//! Flower *Mods*: composable ClientApp middleware (the paper's footnote 2
//! — "All new features (like Flower Mods) will be built on top of
//! [Flower Next]").
//!
//! A [`ClientMod`] has ONE real hook: [`ClientMod::on_message`] — every
//! message of every type flows through it, so a mod written against the
//! message surface intercepts fit, evaluate, analytics queries, and
//! custom verbs alike. The fit/evaluate-specific hooks
//! ([`ClientMod::on_fit`] / [`ClientMod::on_evaluate`]) still exist for
//! convenience — the default `on_message` adapts `Train`/`Evaluate`
//! messages onto them and passes every other type straight through —
//! which is how the differential-privacy and secure-aggregation mods
//! attach to unmodified apps exactly as before.
//!
//! A [`ModStack`] chains mods around any inner [`MessageApp`] with a
//! single message-level recursion (the per-hook trampoline-closure
//! chains of the old design are gone).

use std::cell::RefCell;
use std::sync::Arc;

use crate::flower::clientapp::{ClientApp, Context, EvalOutput, FitOutput, MessageApp, Router};
use crate::flower::message::{ConfigRecord, Message, MessageType};
use crate::flower::records::ArrayRecord;

/// The inner continuation a mod calls to proceed down the chain.
pub type MsgNext<'a> = &'a dyn Fn(&Message, &mut Context) -> anyhow::Result<Message>;
pub type FitNext<'a> = &'a dyn Fn(&ArrayRecord, &ConfigRecord) -> anyhow::Result<FitOutput>;
pub type EvalNext<'a> = &'a dyn Fn(&ArrayRecord, &ConfigRecord) -> anyhow::Result<EvalOutput>;

pub trait ClientMod: Send + Sync {
    fn name(&self) -> &'static str;

    /// THE hook: every message — any [`MessageType`] — flows through
    /// here. The default adapts `Train`/`Evaluate` onto
    /// [`ClientMod::on_fit`] / [`ClientMod::on_evaluate`] (so classic
    /// mods keep working untouched) and forwards everything else down
    /// the chain unchanged. Override to intercept queries and custom
    /// messages, or to act on metadata/context.
    fn on_message(
        &self,
        msg: &Message,
        ctx: &mut Context,
        next: MsgNext,
    ) -> anyhow::Result<Message> {
        match &msg.message_type {
            MessageType::Train => {
                let ctx_cell = RefCell::new(ctx);
                // The FitOutput surface cannot express the reply-side
                // configs / loss channels a message-native Train handler
                // may use: stash them off the inner reply and graft them
                // back onto the rebuilt one, so the fit-hook adaptation
                // is lossless for every reply field the wire carries.
                let extras: RefCell<Option<(ConfigRecord, f64)>> = RefCell::new(None);
                let fit_next = |p: &ArrayRecord, c: &ConfigRecord| -> anyhow::Result<FitOutput> {
                    let mut inner = msg.clone();
                    inner.content.arrays = p.clone();
                    inner.content.configs = c.clone();
                    let mut ctx = ctx_cell.borrow_mut();
                    let reply = next(&inner, &mut **ctx)?;
                    *extras.borrow_mut() =
                        Some((reply.content.configs.clone(), reply.metadata.loss));
                    FitOutput::from_reply(reply)
                };
                let out = self.on_fit(&msg.content.arrays, &msg.content.configs, &fit_next)?;
                let mut reply = out.into_reply(msg);
                if let Some((configs, loss)) = extras.borrow_mut().take() {
                    reply.content.configs = configs;
                    reply.metadata.loss = loss;
                }
                Ok(reply)
            }
            MessageType::Evaluate => {
                let ctx_cell = RefCell::new(ctx);
                // Same grafting for Evaluate: the EvalOutput surface has
                // no slot for reply arrays / configs.
                let extras: RefCell<Option<(ArrayRecord, ConfigRecord)>> = RefCell::new(None);
                let eval_next = |p: &ArrayRecord, c: &ConfigRecord| -> anyhow::Result<EvalOutput> {
                    let mut inner = msg.clone();
                    inner.content.arrays = p.clone();
                    inner.content.configs = c.clone();
                    let mut ctx = ctx_cell.borrow_mut();
                    let reply = next(&inner, &mut **ctx)?;
                    *extras.borrow_mut() =
                        Some((reply.content.arrays.clone(), reply.content.configs.clone()));
                    EvalOutput::from_reply(reply)
                };
                let out =
                    self.on_evaluate(&msg.content.arrays, &msg.content.configs, &eval_next)?;
                let mut reply = out.into_reply(msg);
                if let Some((arrays, configs)) = extras.borrow_mut().take() {
                    reply.content.arrays = arrays;
                    reply.content.configs = configs;
                }
                Ok(reply)
            }
            // Query / Custom: mods that don't override on_message are
            // transparent to non-FL traffic.
            _ => next(msg, ctx),
        }
    }

    /// Fit-shaped convenience hook (default impl over the message
    /// surface — see [`ClientMod::on_message`]).
    fn on_fit(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
        next: FitNext,
    ) -> anyhow::Result<FitOutput> {
        next(parameters, config)
    }

    /// Evaluate-shaped convenience hook.
    fn on_evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
        next: EvalNext,
    ) -> anyhow::Result<EvalOutput> {
        next(parameters, config)
    }
}

/// An app wrapped in an ordered mod chain (first mod is outermost).
/// The chain is a single message-level recursion: one
/// [`ClientMod::on_message`] call per layer, whatever the message type.
pub struct ModStack {
    inner: Arc<dyn MessageApp>,
    mods: Vec<Arc<dyn ClientMod>>,
}

impl ModStack {
    /// Wrap a classic fit/evaluate [`ClientApp`] (mounted via
    /// [`Router::from_client`]) in `mods`.
    pub fn new(app: Arc<dyn ClientApp>, mods: Vec<Arc<dyn ClientMod>>) -> Self {
        Self::over(Arc::new(Router::from_client(app)), mods)
    }

    /// Wrap ANY message app — e.g. a [`Router`] with query/custom
    /// handlers — in `mods`: this is how dp/secagg-style middleware
    /// intercepts non-FL traffic too.
    pub fn over(inner: Arc<dyn MessageApp>, mods: Vec<Arc<dyn ClientMod>>) -> Self {
        Self { inner, mods }
    }

    fn run(&self, idx: usize, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message> {
        if idx == self.mods.len() {
            return self.inner.handle(msg, ctx);
        }
        let next = |m: &Message, c: &mut Context| self.run(idx + 1, m, c);
        self.mods[idx].on_message(msg, ctx, &next)
    }
}

impl MessageApp for ModStack {
    fn handle(&self, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message> {
        self.run(0, msg, ctx)
    }

    fn handles(&self, message_type: &MessageType) -> bool {
        self.inner.handles(message_type)
    }
}

/// Compat surface: a ModStack still works anywhere a fit/evaluate
/// [`ClientApp`] is expected (the calls are synthesized as one-shot
/// `Train`/`Evaluate` messages with a throwaway context — byte-identical
/// results; apps that need the PERSISTENT context run behind the
/// message surface instead).
impl ClientApp for ModStack {
    fn fit(&self, parameters: &ArrayRecord, config: &ConfigRecord) -> anyhow::Result<FitOutput> {
        let node = config.get_i64("node_id").unwrap_or(0) as u64;
        let ins = Message::train(node, parameters.clone(), config.clone());
        let mut ctx = Context::new(0, node);
        FitOutput::from_reply(self.handle(&ins, &mut ctx)?)
    }

    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput> {
        let node = config.get_i64("node_id").unwrap_or(0) as u64;
        let ins = Message::evaluate(node, parameters.clone(), config.clone());
        let mut ctx = Context::new(0, node);
        EvalOutput::from_reply(self.handle(&ins, &mut ctx)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::clientapp::ArithmeticClient;
    use crate::flower::records::{ConfigValue, RecordDict};

    /// Mod that scales returned parameters by a factor.
    struct ScaleMod(f32);

    impl ClientMod for ScaleMod {
        fn name(&self) -> &'static str {
            "scale"
        }
        fn on_fit(
            &self,
            p: &ArrayRecord,
            c: &ConfigRecord,
            next: FitNext,
        ) -> anyhow::Result<FitOutput> {
            let mut out = next(p, c)?;
            let k = self.0 as f64;
            out.parameters = out.parameters.map_f64(|_, _, v| v * k);
            Ok(out)
        }
    }

    /// Mod that counts calls.
    struct TagMod;

    impl ClientMod for TagMod {
        fn name(&self) -> &'static str {
            "tag"
        }
        fn on_fit(
            &self,
            p: &ArrayRecord,
            c: &ConfigRecord,
            next: FitNext,
        ) -> anyhow::Result<FitOutput> {
            let mut out = next(p, c)?;
            out.metrics.push(("tagged".into(), 1.0));
            Ok(out)
        }
    }

    #[test]
    fn empty_stack_is_transparent() {
        let app = ModStack::new(Arc::new(ArithmeticClient { delta: 1.0, n: 2 }), vec![]);
        let out = app
            .fit(&ArrayRecord::from_flat(&[1.0]), &ConfigRecord::new())
            .unwrap();
        assert_eq!(out.parameters.to_flat(), vec![2.0]);
        let ev = app
            .evaluate(&ArrayRecord::from_flat(&[4.0]), &ConfigRecord::new())
            .unwrap();
        assert_eq!(ev.loss, 4.0);
    }

    #[test]
    fn mods_apply_outermost_first() {
        // scale(2) wraps tag: inner fit gives 2.0, tag adds metric,
        // scale doubles -> 4.0.
        let app = ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 2 }),
            vec![Arc::new(ScaleMod(2.0)), Arc::new(TagMod)],
        );
        let out = app
            .fit(&ArrayRecord::from_flat(&[1.0]), &ConfigRecord::new())
            .unwrap();
        assert_eq!(out.parameters.to_flat(), vec![4.0]);
        assert!(out.metrics.iter().any(|(k, _)| k == "tagged"));
    }

    #[test]
    fn mod_errors_propagate() {
        struct FailMod;
        impl ClientMod for FailMod {
            fn name(&self) -> &'static str {
                "fail"
            }
            fn on_fit(
                &self,
                _: &ArrayRecord,
                _: &ConfigRecord,
                _: FitNext,
            ) -> anyhow::Result<FitOutput> {
                anyhow::bail!("mod refused")
            }
        }
        let app = ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 2 }),
            vec![Arc::new(FailMod)],
        );
        assert!(app
            .fit(&ArrayRecord::from_flat(&[1.0]), &ConfigRecord::new())
            .is_err());
    }

    /// A message-level mod: counts EVERY message type it sees (fit,
    /// eval, query, custom) in the persistent context — the "mods
    /// intercept Query and custom messages for free" property.
    struct MeterMod;

    impl ClientMod for MeterMod {
        fn name(&self) -> &'static str {
            "meter"
        }
        fn on_message(
            &self,
            msg: &Message,
            ctx: &mut Context,
            next: MsgNext,
        ) -> anyhow::Result<Message> {
            ctx.state
                .bump(format!("seen_{}", msg.message_type.name()), 1);
            next(msg, ctx)
        }
    }

    #[test]
    fn message_level_mod_sees_all_types() {
        let router = Router::new().on_query(
            |msg: &Message, _ctx: &mut Context| -> anyhow::Result<Message> {
                Ok(msg.reply(RecordDict::default()).with_examples(1))
            },
        );
        let app = ModStack::over(Arc::new(router), vec![Arc::new(MeterMod)]);
        let mut ctx = Context::new(1, 3);
        let q = Message::query(3, ConfigRecord::new());
        app.handle(&q, &mut ctx).unwrap();
        app.handle(&q, &mut ctx).unwrap();
        assert_eq!(ctx.state.get_i64("seen_query"), Some(2));
        // Unhandled custom type: the mod still saw it, the router's
        // typed error propagates.
        let c = Message::new(MessageType::custom("nope"), 3, RecordDict::default());
        assert!(app.handle(&c, &mut ctx).is_err());
        assert_eq!(ctx.state.get_i64("seen_nope"), Some(1));
    }

    #[test]
    fn default_hook_preserves_reply_configs_and_loss_through_mods() {
        // A message-native Train handler using the reply-side configs /
        // loss channels, wrapped in a mod that only implements on_fit
        // hooks (TagMod): the default Train adaptation must not strip
        // those channels.
        use crate::flower::records::ArrayRecord as AR;
        let router = Router::new().on_train(
            |msg: &Message, _ctx: &mut Context| -> anyhow::Result<Message> {
                let mut out = ConfigRecord::new();
                out.insert("schema", ConfigValue::Str("v2".into()));
                let mut reply = msg.reply(crate::flower::records::RecordDict {
                    arrays: msg.content.arrays.clone(),
                    metrics: crate::flower::records::MetricRecord::new(),
                    configs: out,
                });
                reply = reply.with_examples(3).with_loss(0.125);
                Ok(reply)
            },
        );
        let app = ModStack::over(Arc::new(router), vec![Arc::new(TagMod)]);
        let mut ctx = Context::new(1, 2);
        let ins = Message::train(2, AR::from_flat(&[1.0]), ConfigRecord::new());
        let reply = app.handle(&ins, &mut ctx).unwrap();
        assert_eq!(reply.content.configs.get_str("schema"), Some("v2"));
        assert_eq!(reply.metadata.loss, 0.125);
        assert_eq!(reply.metadata.num_examples, 3);
        assert!(
            reply.content.metrics.iter().any(|(k, _)| k == "tagged"),
            "the on_fit hook still ran"
        );
    }

    #[test]
    fn fit_hooks_run_via_message_chain_with_context() {
        // An on_fit mod (ScaleMod) composed with a message-level mod
        // (MeterMod): both layers apply, in order, over one message
        // recursion.
        let app = ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 2 }),
            vec![Arc::new(MeterMod), Arc::new(ScaleMod(2.0))],
        );
        let mut ctx = Context::new(1, 4);
        let ins = Message::train(
            4,
            ArrayRecord::from_flat(&[1.0]),
            ConfigRecord::from_pairs(vec![("node_id".to_string(), ConfigValue::I64(4))]),
        );
        let reply = app.handle(&ins, &mut ctx).unwrap();
        let out = FitOutput::from_reply(reply).unwrap();
        assert_eq!(out.parameters.to_flat(), vec![4.0]);
        assert_eq!(ctx.state.get_i64("seen_train"), Some(1));
    }
}
