//! Flower *Mods*: composable ClientApp middleware (the paper's footnote 2
//! — "All new features (like Flower Mods) will be built on top of
//! [Flower Next]"). A [`ClientMod`] wraps fit/evaluate calls; a
//! [`ModStack`] chains mods around any inner [`ClientApp`] without the
//! app changing — which is how the differential-privacy and secure-
//! aggregation features the paper advertises ("rich built-in differential
//! privacy and secure aggregation support") attach to unmodified apps.

use std::sync::Arc;

use crate::flower::clientapp::{ClientApp, EvalOutput, FitOutput};
use crate::flower::message::ConfigRecord;
use crate::flower::records::ArrayRecord;

/// The inner continuation a mod calls to proceed down the chain.
pub type FitNext<'a> = &'a dyn Fn(&ArrayRecord, &ConfigRecord) -> anyhow::Result<FitOutput>;
pub type EvalNext<'a> = &'a dyn Fn(&ArrayRecord, &ConfigRecord) -> anyhow::Result<EvalOutput>;

pub trait ClientMod: Send + Sync {
    fn name(&self) -> &'static str;

    fn on_fit(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
        next: FitNext,
    ) -> anyhow::Result<FitOutput> {
        next(parameters, config)
    }

    fn on_evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
        next: EvalNext,
    ) -> anyhow::Result<EvalOutput> {
        next(parameters, config)
    }
}

/// An app wrapped in an ordered mod chain (first mod is outermost).
pub struct ModStack {
    app: Arc<dyn ClientApp>,
    mods: Vec<Arc<dyn ClientMod>>,
}

impl ModStack {
    pub fn new(app: Arc<dyn ClientApp>, mods: Vec<Arc<dyn ClientMod>>) -> Self {
        Self { app, mods }
    }

    fn run_fit(
        &self,
        idx: usize,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<FitOutput> {
        if idx == self.mods.len() {
            return self.app.fit(parameters, config);
        }
        let next = |p: &ArrayRecord, c: &ConfigRecord| self.run_fit(idx + 1, p, c);
        self.mods[idx].on_fit(parameters, config, &next)
    }

    fn run_eval(
        &self,
        idx: usize,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput> {
        if idx == self.mods.len() {
            return self.app.evaluate(parameters, config);
        }
        let next = |p: &ArrayRecord, c: &ConfigRecord| self.run_eval(idx + 1, p, c);
        self.mods[idx].on_evaluate(parameters, config, &next)
    }
}

impl ClientApp for ModStack {
    fn fit(&self, parameters: &ArrayRecord, config: &ConfigRecord) -> anyhow::Result<FitOutput> {
        self.run_fit(0, parameters, config)
    }

    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput> {
        self.run_eval(0, parameters, config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::clientapp::ArithmeticClient;

    /// Mod that scales returned parameters by a factor.
    struct ScaleMod(f32);

    impl ClientMod for ScaleMod {
        fn name(&self) -> &'static str {
            "scale"
        }
        fn on_fit(
            &self,
            p: &ArrayRecord,
            c: &ConfigRecord,
            next: FitNext,
        ) -> anyhow::Result<FitOutput> {
            let mut out = next(p, c)?;
            let k = self.0 as f64;
            out.parameters = out.parameters.map_f64(|_, _, v| v * k);
            Ok(out)
        }
    }

    /// Mod that counts calls.
    struct TagMod;

    impl ClientMod for TagMod {
        fn name(&self) -> &'static str {
            "tag"
        }
        fn on_fit(
            &self,
            p: &ArrayRecord,
            c: &ConfigRecord,
            next: FitNext,
        ) -> anyhow::Result<FitOutput> {
            let mut out = next(p, c)?;
            out.metrics.push(("tagged".into(), 1.0));
            Ok(out)
        }
    }

    #[test]
    fn empty_stack_is_transparent() {
        let app = ModStack::new(Arc::new(ArithmeticClient { delta: 1.0, n: 2 }), vec![]);
        let out = app.fit(&ArrayRecord::from_flat(&[1.0]), &vec![]).unwrap();
        assert_eq!(out.parameters.to_flat(), vec![2.0]);
        let ev = app
            .evaluate(&ArrayRecord::from_flat(&[4.0]), &vec![])
            .unwrap();
        assert_eq!(ev.loss, 4.0);
    }

    #[test]
    fn mods_apply_outermost_first() {
        // scale(2) wraps tag: inner fit gives 2.0, tag adds metric,
        // scale doubles -> 4.0.
        let app = ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 2 }),
            vec![Arc::new(ScaleMod(2.0)), Arc::new(TagMod)],
        );
        let out = app.fit(&ArrayRecord::from_flat(&[1.0]), &vec![]).unwrap();
        assert_eq!(out.parameters.to_flat(), vec![4.0]);
        assert!(out.metrics.iter().any(|(k, _)| k == "tagged"));
    }

    #[test]
    fn mod_errors_propagate() {
        struct FailMod;
        impl ClientMod for FailMod {
            fn name(&self) -> &'static str {
                "fail"
            }
            fn on_fit(
                &self,
                _: &ArrayRecord,
                _: &ConfigRecord,
                _: FitNext,
            ) -> anyhow::Result<FitOutput> {
                anyhow::bail!("mod refused")
            }
        }
        let app = ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 2 }),
            vec![Arc::new(FailMod)],
        );
        assert!(app.fit(&ArrayRecord::from_flat(&[1.0]), &vec![]).is_err());
    }
}
