//! Periodic checkpoints: a full snapshot of the SuperLink's run state
//! (plus each run's opaque driver blob) in one CRC-framed file,
//! replaced atomically via tmp + rename so a crash mid-checkpoint
//! leaves the previous checkpoint intact.
//!
//! A checkpoint records the WAL offset it was cut at: recovery loads
//! the snapshot and replays only the WAL tail past that offset, which
//! is what bounds recovery time as runs get long.
//!
//! [`DriverCkpt`] is the ServerApp-side companion: the round/commit
//! cursor, current parameters, history so far, exported strategy state,
//! and — mid-fit — the accumulator snapshot. It rides inside
//! [`Checkpoint::drivers`] as opaque bytes so the link stays agnostic
//! of driver internals.

use std::io::Write as _;
use std::path::Path;

use super::wal::{crc32, read_task_ins, read_task_res, write_task_ins, write_task_res};
use crate::flower::asyncfed::AsyncCommit;
use crate::flower::committee::Verdict;
use crate::flower::message::{read_metrics, read_record, write_metrics, write_record};
use crate::flower::message::{TaskIns, TaskRes};
use crate::flower::records::{ArrayRecord, MetricRecord};
use crate::flower::serverapp::{History, Participation, RoundRecord};
use crate::flower::strategy::FitRes;
use crate::util::bytes::{Bytes, FrameReader, WireError, Writer};

// ---------------------------------------------------------------------------
// Link-side snapshot types
// ---------------------------------------------------------------------------

/// A delivered-but-unresolved task at snapshot time. Durable links
/// retain every in-flight instruction (not just redeliverable ones) so
/// the checkpoint can re-queue it to the SAME node after recovery —
/// deterministic re-execution is what keeps recovery bit-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct InflightSnapshot {
    pub task_id: u64,
    pub node_id: u64,
    pub attempt: u32,
    pub ins: Option<TaskIns>,
}

/// One run's full [`crate::flower::superlink::RunState`], in sorted,
/// deterministic order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunSnapshot {
    pub run_id: u64,
    pub active: bool,
    /// Queued-undelivered instructions keyed by assigned node.
    pub pending: Vec<(u64, Vec<TaskIns>)>,
    /// Delivered-unresolved tasks.
    pub inflight: Vec<InflightSnapshot>,
    /// Accepted, unclaimed results (model versions already stamped).
    pub results: Vec<TaskRes>,
    pub failed: Vec<(u64, String)>,
    pub done: Vec<u64>,
    /// Per-task model version (stamped onto the result at acceptance).
    pub task_version: Vec<(u64, u64)>,
    /// Nodes that acknowledged this run's retirement.
    pub acked: Vec<u64>,
}

/// The whole link, cut at `wal_offset`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    /// WAL byte offset this snapshot is consistent with: recovery
    /// replays only records past it.
    pub wal_offset: u64,
    pub next_node: u64,
    pub next_task: u64,
    pub runs: Vec<RunSnapshot>,
    /// Latest opaque driver blob per run id ([`DriverCkpt`] bytes).
    pub drivers: Vec<(u64, Vec<u8>)>,
}

fn write_ins_list(w: &mut Writer, list: &[TaskIns]) {
    w.u32(list.len() as u32);
    for ins in list {
        write_task_ins(w, ins);
    }
}

fn read_ins_list(r: &mut FrameReader) -> Result<Vec<TaskIns>, WireError> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        out.push(read_task_ins(r)?);
    }
    Ok(out)
}

fn write_run(w: &mut Writer, run: &RunSnapshot) {
    w.u64(run.run_id);
    w.u8(run.active as u8);
    w.u32(run.pending.len() as u32);
    for (node, list) in &run.pending {
        w.u64(*node);
        write_ins_list(w, list);
    }
    w.u32(run.inflight.len() as u32);
    for t in &run.inflight {
        w.u64(t.task_id);
        w.u64(t.node_id);
        w.u32(t.attempt);
        match &t.ins {
            Some(ins) => {
                w.u8(1);
                write_task_ins(w, ins);
            }
            None => w.u8(0),
        }
    }
    w.u32(run.results.len() as u32);
    for res in &run.results {
        write_task_res(w, res);
    }
    w.u32(run.failed.len() as u32);
    for (tid, reason) in &run.failed {
        w.u64(*tid);
        w.str(reason);
    }
    w.u32(run.done.len() as u32);
    for tid in &run.done {
        w.u64(*tid);
    }
    w.u32(run.task_version.len() as u32);
    for (tid, v) in &run.task_version {
        w.u64(*tid);
        w.u64(*v);
    }
    w.u32(run.acked.len() as u32);
    for node in &run.acked {
        w.u64(*node);
    }
}

fn read_run(r: &mut FrameReader) -> Result<RunSnapshot, WireError> {
    let run_id = r.u64()?;
    let active = r.u8()? != 0;
    let n = r.u32()? as usize;
    let mut pending = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let node = r.u64()?;
        pending.push((node, read_ins_list(r)?));
    }
    let n = r.u32()? as usize;
    let mut inflight = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let task_id = r.u64()?;
        let node_id = r.u64()?;
        let attempt = r.u32()?;
        let ins = match r.u8()? {
            0 => None,
            _ => Some(read_task_ins(r)?),
        };
        inflight.push(InflightSnapshot {
            task_id,
            node_id,
            attempt,
            ins,
        });
    }
    let n = r.u32()? as usize;
    let mut results = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        results.push(read_task_res(r)?);
    }
    let n = r.u32()? as usize;
    let mut failed = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        failed.push((r.u64()?, r.str()?));
    }
    let n = r.u32()? as usize;
    let mut done = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        done.push(r.u64()?);
    }
    let n = r.u32()? as usize;
    let mut task_version = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        task_version.push((r.u64()?, r.u64()?));
    }
    let n = r.u32()? as usize;
    let mut acked = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        acked.push(r.u64()?);
    }
    Ok(RunSnapshot {
        run_id,
        active,
        pending,
        inflight,
        results,
        failed,
        done,
        task_version,
        acked,
    })
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.wal_offset);
        w.u64(self.next_node);
        w.u64(self.next_task);
        w.u32(self.runs.len() as u32);
        for run in &self.runs {
            write_run(&mut w, run);
        }
        w.u32(self.drivers.len() as u32);
        for (run_id, blob) in &self.drivers {
            w.u64(*run_id);
            w.bytes(blob);
        }
        w.into_bytes()
    }

    pub fn decode(payload: Bytes) -> Result<Checkpoint, WireError> {
        let mut r = FrameReader::new(payload);
        let wal_offset = r.u64()?;
        let next_node = r.u64()?;
        let next_task = r.u64()?;
        let n = r.u32()? as usize;
        let mut runs = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            runs.push(read_run(&mut r)?);
        }
        let n = r.u32()? as usize;
        let mut drivers = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let run_id = r.u64()?;
            let blob = r.bytes_shared()?;
            drivers.push((run_id, blob.as_slice().to_vec()));
        }
        Ok(Checkpoint {
            wal_offset,
            next_node,
            next_task,
            runs,
            drivers,
        })
    }

    /// Atomically replace the checkpoint at `path` (write tmp, fsync,
    /// rename): a crash mid-write leaves the previous checkpoint valid.
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        let payload = self.encode();
        let mut buf = Vec::with_capacity(payload.len() + 8);
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        buf.extend_from_slice(&payload);
        let tmp = path.with_extension("ckpt.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_data()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        crate::telemetry::bump("checkpoint.count", 1);
        crate::telemetry::bump("checkpoint.bytes", buf.len() as i64);
        Ok(())
    }

    /// Load the checkpoint at `path`; `None` (with a warning) when the
    /// file is missing, short, CRC-damaged, or undecodable — recovery
    /// then replays the WAL from offset 0 instead of trusting garbage.
    pub fn read(path: &Path) -> Option<Checkpoint> {
        let data = match std::fs::read(path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                log::warn!("checkpoint {}: unreadable: {e}", path.display());
                return None;
            }
        };
        if data.len() < 8 {
            log::warn!("checkpoint {}: short file, ignoring", path.display());
            return None;
        }
        let len = u32::from_le_bytes([data[0], data[1], data[2], data[3]]) as usize;
        let want = u32::from_le_bytes([data[4], data[5], data[6], data[7]]);
        if data.len() != len + 8 {
            log::warn!("checkpoint {}: truncated, ignoring", path.display());
            return None;
        }
        if crc32(&data[8..]) != want {
            log::warn!("checkpoint {}: CRC mismatch, ignoring", path.display());
            return None;
        }
        let shared = Bytes::from_vec(data);
        match Checkpoint::decode(shared.slice(8, len)) {
            Ok(c) => Some(c),
            Err(e) => {
                log::warn!("checkpoint {}: undecodable: {e}", path.display());
                None
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Driver-side checkpoint blob
// ---------------------------------------------------------------------------

/// Mid-fit accumulator snapshot: the round's task ids, the results
/// folded so far (via [`crate::flower::strategy::FitAgg::snapshot`]),
/// and the per-node fit metadata the metric aggregation needs.
#[derive(Clone, Debug, PartialEq)]
pub struct FitCkpt {
    pub task_ids: Vec<u64>,
    pub results: Vec<FitRes>,
    pub fit_meta: Vec<(u64, u64, MetricRecord)>,
}

/// Async driver state at a commit boundary. Dispatch bookkeeping
/// (which tasks are outstanding on which nodes) is NOT stored here:
/// the recovered link knows it exactly (`open_tasks`), including the
/// dispatches that happened after this checkpoint was cut.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncCkpt {
    pub buffer_size: u64,
    pub max_staleness: u64,
    /// Committed model version at the checkpoint.
    pub version: u64,
    pub total_folded: u64,
}

/// Where the driver was when the blob was cut.
#[derive(Clone, Debug, PartialEq)]
pub enum DriverPhase {
    /// Sync driver, about to start round [`DriverCkpt::round`]; resume
    /// re-runs the round from scratch (deterministic clients + the
    /// link's done-set make the re-run fold identical results).
    RoundStart,
    /// Sync driver, mid-fit of round [`DriverCkpt::round`].
    MidFit(FitCkpt),
    /// Async driver at a commit boundary; [`DriverCkpt::round`] is the
    /// next commit index.
    AsyncCommit(AsyncCkpt),
}

/// The ServerApp's resume blob, stored via `Grid::checkpoint_run` and
/// read back by `ServerApp::resume` after `SuperLink::recover`.
#[derive(Clone, Debug, PartialEq)]
pub struct DriverCkpt {
    /// Next round (RoundStart), current round (MidFit), or next commit
    /// (AsyncCommit).
    pub round: u64,
    /// Parameters entering that round/commit.
    pub parameters: ArrayRecord,
    /// History completed so far.
    pub history: History,
    /// `Strategy::export_state()` at the cut (None for stateless).
    pub strategy_state: Option<ArrayRecord>,
    pub phase: DriverPhase,
}

fn write_fit_res(w: &mut Writer, res: &FitRes) {
    w.u64(res.node_id);
    write_record(w, &res.parameters);
    w.u64(res.num_examples);
    write_metrics(w, &res.metrics);
}

fn read_fit_res(r: &mut FrameReader) -> Result<FitRes, WireError> {
    Ok(FitRes {
        node_id: r.u64()?,
        parameters: read_record(r)?,
        num_examples: r.u64()?,
        metrics: read_metrics(r)?,
    })
}

fn write_history(w: &mut Writer, h: &History) {
    w.u32(h.rounds.len() as u32);
    for rec in &h.rounds {
        w.u64(rec.round);
        write_metrics(w, &rec.fit_metrics);
        match rec.eval_loss {
            Some(l) => {
                w.u8(1);
                w.f64(l);
            }
            None => w.u8(0),
        }
        write_metrics(w, &rec.eval_metrics);
        w.u32(rec.per_client_eval.len() as u32);
        for (node, loss, m) in &rec.per_client_eval {
            w.u64(*node);
            w.f64(*loss);
            write_metrics(w, m);
        }
        w.u64(rec.participation.sampled as u64);
        w.u64(rec.participation.completed as u64);
        w.u64(rec.participation.dropped as u64);
        w.u64(rec.participation.quarantined as u64);
        w.u32(rec.verdicts.len() as u32);
        for v in &rec.verdicts {
            w.u64(v.node_id);
            w.u8(v.quarantined as u8);
            w.str(&v.reason);
            w.f64(v.score);
        }
    }
    w.u32(h.commits.len() as u32);
    for c in &h.commits {
        w.u64(c.version);
        w.u64(c.results_folded as u64);
        w.u64(c.max_staleness);
    }
    write_record(w, &h.parameters);
}

fn read_history(r: &mut FrameReader) -> Result<History, WireError> {
    let n = r.u32()? as usize;
    let mut rounds = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let round = r.u64()?;
        let fit_metrics = read_metrics(r)?;
        let eval_loss = match r.u8()? {
            0 => None,
            _ => Some(r.f64()?),
        };
        let eval_metrics = read_metrics(r)?;
        let m = r.u32()? as usize;
        let mut per_client_eval = Vec::with_capacity(m.min(1 << 16));
        for _ in 0..m {
            per_client_eval.push((r.u64()?, r.f64()?, read_metrics(r)?));
        }
        let participation = Participation {
            sampled: r.u64()? as usize,
            completed: r.u64()? as usize,
            dropped: r.u64()? as usize,
            quarantined: r.u64()? as usize,
        };
        let m = r.u32()? as usize;
        let mut verdicts = Vec::with_capacity(m.min(1 << 16));
        for _ in 0..m {
            verdicts.push(Verdict {
                node_id: r.u64()?,
                quarantined: r.u8()? != 0,
                reason: r.str()?,
                score: r.f64()?,
            });
        }
        rounds.push(RoundRecord {
            round,
            fit_metrics,
            eval_loss,
            eval_metrics,
            per_client_eval,
            participation,
            verdicts,
        });
    }
    let n = r.u32()? as usize;
    let mut commits = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        commits.push(AsyncCommit {
            version: r.u64()?,
            results_folded: r.u64()? as usize,
            max_staleness: r.u64()?,
        });
    }
    let parameters = read_record(r)?;
    Ok(History {
        rounds,
        commits,
        parameters,
    })
}

impl DriverCkpt {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.round);
        write_record(&mut w, &self.parameters);
        match &self.strategy_state {
            Some(s) => {
                w.u8(1);
                write_record(&mut w, s);
            }
            None => w.u8(0),
        }
        write_history(&mut w, &self.history);
        match &self.phase {
            DriverPhase::RoundStart => w.u8(0),
            DriverPhase::MidFit(fit) => {
                w.u8(1);
                w.u32(fit.task_ids.len() as u32);
                for t in &fit.task_ids {
                    w.u64(*t);
                }
                w.u32(fit.results.len() as u32);
                for res in &fit.results {
                    write_fit_res(&mut w, res);
                }
                w.u32(fit.fit_meta.len() as u32);
                for (node, examples, m) in &fit.fit_meta {
                    w.u64(*node);
                    w.u64(*examples);
                    write_metrics(&mut w, m);
                }
            }
            DriverPhase::AsyncCommit(a) => {
                w.u8(2);
                w.u64(a.buffer_size);
                w.u64(a.max_staleness);
                w.u64(a.version);
                w.u64(a.total_folded);
            }
        }
        w.into_bytes()
    }

    pub fn decode(blob: &[u8]) -> anyhow::Result<DriverCkpt> {
        let mut r = FrameReader::new(Bytes::copy_from_slice(blob));
        let round = r.u64()?;
        let parameters = read_record(&mut r)?;
        let strategy_state = match r.u8()? {
            0 => None,
            _ => Some(read_record(&mut r)?),
        };
        let history = read_history(&mut r)?;
        let phase = match r.u8()? {
            0 => DriverPhase::RoundStart,
            1 => {
                let n = r.u32()? as usize;
                let mut task_ids = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    task_ids.push(r.u64()?);
                }
                let n = r.u32()? as usize;
                let mut results = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    results.push(read_fit_res(&mut r)?);
                }
                let n = r.u32()? as usize;
                let mut fit_meta = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    fit_meta.push((r.u64()?, r.u64()?, read_metrics(&mut r)?));
                }
                DriverPhase::MidFit(FitCkpt {
                    task_ids,
                    results,
                    fit_meta,
                })
            }
            2 => DriverPhase::AsyncCommit(AsyncCkpt {
                buffer_size: r.u64()?,
                max_staleness: r.u64()?,
                version: r.u64()?,
                total_folded: r.u64()?,
            }),
            t => anyhow::bail!("driver checkpoint: unknown phase tag {t}"),
        };
        Ok(DriverCkpt {
            round,
            parameters,
            history,
            strategy_state,
            phase,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::message::MessageType;
    use crate::flower::persist::test_dir;

    fn sample_checkpoint() -> Checkpoint {
        let ins = TaskIns {
            task_id: 5,
            run_id: 1,
            round: 2,
            message_type: MessageType::Train,
            attempt: 0,
            redeliver: false,
            model_version: 1,
            parameters: ArrayRecord::from_flat(&[0.25; 4]),
            config: Default::default(),
        };
        let res = TaskRes {
            task_id: 4,
            run_id: 1,
            node_id: 2,
            error: String::new(),
            message_type: MessageType::Train,
            parameters: ArrayRecord::from_flat(&[1.5; 4]),
            num_examples: 12,
            loss: 0.0,
            metrics: MetricRecord::from_pairs(vec![("acc".into(), 0.5)]),
            configs: Default::default(),
            model_version: 1,
        };
        Checkpoint {
            wal_offset: 321,
            next_node: 4,
            next_task: 9,
            runs: vec![RunSnapshot {
                run_id: 1,
                active: true,
                pending: vec![(3, vec![ins.clone()])],
                inflight: vec![
                    InflightSnapshot {
                        task_id: 6,
                        node_id: 1,
                        attempt: 1,
                        ins: Some(ins),
                    },
                    InflightSnapshot {
                        task_id: 7,
                        node_id: 2,
                        attempt: 0,
                        ins: None,
                    },
                ],
                results: vec![res],
                failed: vec![(2, "node 9 unavailable".into())],
                done: vec![2, 4],
                task_version: vec![(5, 1), (6, 1), (7, 1)],
                acked: vec![1],
            }],
            drivers: vec![(1, vec![9, 8, 7])],
        }
    }

    #[test]
    fn checkpoint_file_roundtrip() {
        let dir = test_dir("ckpt-roundtrip");
        let path = dir.join("superlink.ckpt");
        let ckpt = sample_checkpoint();
        ckpt.write(&path).unwrap();
        let back = Checkpoint::read(&path).unwrap();
        assert_eq!(back, ckpt);
        // Overwrite is atomic-replace: a second write still reads back.
        let mut ckpt2 = ckpt.clone();
        ckpt2.wal_offset = 999;
        ckpt2.write(&path).unwrap();
        assert_eq!(Checkpoint::read(&path).unwrap().wal_offset, 999);
    }

    #[test]
    fn corrupt_or_missing_checkpoint_is_none() {
        let dir = test_dir("ckpt-corrupt");
        let path = dir.join("superlink.ckpt");
        assert!(Checkpoint::read(&path).is_none(), "missing file");
        sample_checkpoint().write(&path).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        let at = data.len() / 2;
        data[at] ^= 0x10;
        std::fs::write(&path, &data).unwrap();
        assert!(Checkpoint::read(&path).is_none(), "bit flip");
        std::fs::write(&path, b"tiny").unwrap();
        assert!(Checkpoint::read(&path).is_none(), "short file");
    }

    #[test]
    fn driver_ckpt_roundtrip_all_phases() {
        let history = History {
            rounds: vec![RoundRecord {
                round: 1,
                fit_metrics: MetricRecord::from_pairs(vec![("loss".into(), 0.25)]),
                eval_loss: Some(0.5),
                eval_metrics: MetricRecord::default(),
                per_client_eval: vec![(1, 0.5, MetricRecord::default())],
                participation: Participation {
                    sampled: 3,
                    completed: 2,
                    dropped: 0,
                    quarantined: 1,
                },
                verdicts: vec![Verdict {
                    node_id: 2,
                    quarantined: true,
                    reason: "update distance outlier".into(),
                    score: 12.5,
                }],
            }],
            commits: vec![AsyncCommit {
                version: 1,
                results_folded: 2,
                max_staleness: 0,
            }],
            parameters: ArrayRecord::from_flat(&[2.0; 3]),
        };
        let phases = vec![
            DriverPhase::RoundStart,
            DriverPhase::MidFit(FitCkpt {
                task_ids: vec![4, 5, 6],
                results: vec![FitRes {
                    node_id: 2,
                    parameters: ArrayRecord::from_flat(&[1.0; 3]),
                    num_examples: 7,
                    metrics: MetricRecord::default(),
                }],
                fit_meta: vec![(2, 7, MetricRecord::default())],
            }),
            DriverPhase::AsyncCommit(AsyncCkpt {
                buffer_size: 4,
                max_staleness: 0,
                version: 3,
                total_folded: 12,
            }),
        ];
        for phase in phases {
            let ckpt = DriverCkpt {
                round: 2,
                parameters: ArrayRecord::from_flat(&[0.5; 3]),
                history: history.clone(),
                strategy_state: Some(ArrayRecord::from_flat(&[9.0])),
                phase,
            };
            let back = DriverCkpt::decode(&ckpt.encode()).unwrap();
            assert_eq!(back, ckpt);
            assert!(back.parameters.bits_equal(&ckpt.parameters));
        }
    }
}
