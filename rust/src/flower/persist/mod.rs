//! Durability for the SuperLink: write-ahead log, checkpoints, and
//! bit-identical crash recovery.
//!
//! Every state transition the link makes — run registered, task
//! queued/delivered/redelivered/failed, result accepted, async fold
//! and commit, run finished — is appended to a length-prefixed,
//! CRC-framed WAL ([`wal`]). With [`Durability::Checkpointed`], a
//! full [`checkpoint::Checkpoint`] of run state (plus each driver's
//! opaque resume blob) is cut every `every_results` accepted results,
//! bounding recovery to the WAL tail past the checkpoint.
//!
//! What is journaled: the link's task/result/done-set state, stamped
//! model versions, async folds and commits. What is NOT journaled:
//! node registrations (leases are ephemeral — survivors re-register
//! with pinned ids after recovery) and result claims (a result handed
//! to a driver that crashed before folding it replays back into the
//! recovered link and is claimed again; the done-set makes folding
//! exactly-once). Secret-aggregation caveat: `SecAggFedAvg` declines
//! accumulator snapshots (masked pairwise sums must never be
//! persisted partially), so its runs recover to the last round
//! boundary rather than mid-fit.
//!
//! Recovering after a crash:
//!
//! ```no_run
//! use flarelink::flower::persist::Durability;
//! use flarelink::flower::superlink::{LinkConfig, SuperLink};
//!
//! # fn main() -> anyhow::Result<()> {
//! let dur = Durability::Checkpointed { dir: "/tmp/link".into(), every_results: 8 };
//! // A fresh durable link journals as it goes ...
//! let link = SuperLink::with_durability(LinkConfig::default(), dur.clone())?;
//! // ... and after a crash, `recover` replays checkpoint + WAL tail,
//! // re-queues in-flight tasks to their original nodes, and resumes.
//! let link = SuperLink::recover(LinkConfig::default(), dur)?;
//! # Ok(()) }
//! ```

pub mod checkpoint;
pub mod recovery;
pub mod wal;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use self::checkpoint::Checkpoint;
use self::recovery::RecoveredState;
use self::wal::{Wal, WalRecord};

/// WAL file name inside a durability directory.
pub const WAL_FILE: &str = "superlink.wal";
/// Checkpoint file name inside a durability directory.
pub const CKPT_FILE: &str = "superlink.ckpt";

/// How (and whether) a SuperLink journals its state.
#[derive(Clone, Debug, Default)]
pub enum Durability {
    /// No journaling (the pre-existing in-memory behavior).
    #[default]
    Off,
    /// WAL only: every transition is journaled; recovery replays the
    /// whole log. No driver-side checkpoints, so drivers resume at
    /// run granularity.
    Wal { dir: PathBuf },
    /// WAL plus a full checkpoint every `every_results` accepted
    /// results. Drivers store resume blobs, so recovery continues
    /// mid-round / mid-commit-window.
    Checkpointed { dir: PathBuf, every_results: u64 },
}

impl Durability {
    pub fn dir(&self) -> Option<&Path> {
        match self {
            Durability::Off => None,
            Durability::Wal { dir } | Durability::Checkpointed { dir, .. } => Some(dir),
        }
    }
}

/// The link's handle on its durability directory: the open WAL, the
/// checkpoint cadence counter, and the drivers' latest resume blobs.
///
/// Lock order: callers (the SuperLink) take the run-map read lock,
/// then at most one run's state mutex, then the WAL mutex — the WAL is
/// a leaf lock, which also serializes appends against checkpoint
/// offset capture. (Checkpointing locks ALL run mutexes in ascending
/// run-id order before the WAL, compatible with the same order.)
pub struct Persistor {
    dir: PathBuf,
    wal: Mutex<Wal>,
    every_results: Option<u64>,
    results_since: AtomicU64,
    drivers: Mutex<BTreeMap<u64, Vec<u8>>>,
}

impl Persistor {
    /// Start a fresh journal: truncates any prior WAL and removes any
    /// prior checkpoint (a fresh link must not resurrect old state).
    pub fn create(dir: &Path, every_results: Option<u64>) -> anyhow::Result<Persistor> {
        std::fs::create_dir_all(dir)?;
        let _ = std::fs::remove_file(dir.join(CKPT_FILE));
        let wal = Wal::create(&dir.join(WAL_FILE))?;
        Ok(Persistor {
            dir: dir.to_path_buf(),
            wal: Mutex::new(wal),
            every_results,
            results_since: AtomicU64::new(0),
            drivers: Mutex::new(BTreeMap::new()),
        })
    }

    /// Re-open the journal after recovery, truncating any torn WAL
    /// suffix and adopting the recovered drivers' blobs.
    pub fn resume(
        dir: &Path,
        every_results: Option<u64>,
        state: &RecoveredState,
    ) -> anyhow::Result<Persistor> {
        std::fs::create_dir_all(dir)?;
        let wal = Wal::open_at(&dir.join(WAL_FILE), state.wal_valid_len)?;
        Ok(Persistor {
            dir: dir.to_path_buf(),
            wal: Mutex::new(wal),
            every_results,
            results_since: AtomicU64::new(0),
            drivers: Mutex::new(state.drivers.iter().cloned().collect()),
        })
    }

    /// Append one record. Journal failures are logged and counted
    /// (`wal.append_errors`), never panicked on: the link keeps
    /// serving, degraded to in-memory durability.
    pub fn append(&self, rec: &WalRecord) {
        let mut wal = self.wal.lock().unwrap();
        if let Err(e) = wal.append(rec) {
            crate::telemetry::bump("wal.append_errors", 1);
            log::error!("wal append failed ({}): {e}", self.dir.display());
        }
    }

    /// Note an accepted result for checkpoint cadence.
    pub fn note_result(&self) {
        self.results_since.fetch_add(1, Ordering::Relaxed);
    }

    /// True when enough results accumulated since the last checkpoint.
    pub fn checkpoint_due(&self) -> bool {
        self.every_results
            .is_some_and(|n| self.results_since.load(Ordering::Relaxed) >= n)
    }

    pub fn wants_checkpoints(&self) -> bool {
        self.every_results.is_some()
    }

    pub fn set_driver(&self, run_id: u64, blob: Vec<u8>) {
        self.drivers.lock().unwrap().insert(run_id, blob);
    }

    pub fn driver(&self, run_id: u64) -> Option<Vec<u8>> {
        self.drivers.lock().unwrap().get(&run_id).cloned()
    }

    pub fn drivers_vec(&self) -> Vec<(u64, Vec<u8>)> {
        self.drivers
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, v.clone()))
            .collect()
    }

    /// Current WAL length. Callers capture this under the runs lock so
    /// the checkpoint offset is consistent with the snapshot.
    pub fn wal_offset(&self) -> u64 {
        self.wal.lock().unwrap().offset()
    }

    /// Write `ckpt` atomically; resets the cadence counter on success.
    /// Failures are logged and counted (`checkpoint.errors`).
    pub fn write_checkpoint(&self, ckpt: &Checkpoint) {
        match ckpt.write(&self.dir.join(CKPT_FILE)) {
            Ok(()) => {
                self.results_since.store(0, Ordering::Relaxed);
            }
            Err(e) => {
                crate::telemetry::bump("checkpoint.errors", 1);
                log::error!("checkpoint write failed ({}): {e}", self.dir.display());
            }
        }
    }
}

/// Unique scratch directory for persistence tests.
#[cfg(test)]
pub(crate) fn test_dir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "flarelink-persist-{}-{}-{}",
        std::process::id(),
        tag,
        N.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}
