//! Write-ahead log: length-prefixed, CRC-framed records of every
//! SuperLink state transition.
//!
//! On-disk format, repeated until EOF:
//!
//! ```text
//! [u32 le payload_len][u32 le crc32(payload)][payload bytes]
//! ```
//!
//! The payload is a [`WalRecord`] encoded with the same record codec the
//! wire uses, so journaled instructions and results round-trip
//! bit-exactly. A crash can tear the tail of the log mid-frame;
//! [`scan`] stops at the first truncated, CRC-failing, or undecodable
//! frame and reports the valid prefix — recovery truncates the file
//! there and NEVER replays a record that fails its checksum.
//!
//! Appends go straight to the kernel via `write_all` (a `File` has no
//! userspace buffer), so the in-process crash simulation used by the
//! chaos tests loses nothing. There is deliberately no fsync per
//! append: the subsystem models *process* crash consistency; a
//! deployment that must survive power loss would add `sync_data` on the
//! commit-boundary records.

use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use crate::flower::message::{
    read_config, read_message_type, read_metrics, read_record, write_config, write_message_type,
    write_metrics, write_record, TaskIns, TaskRes,
};
use crate::util::bytes::{Bytes, FrameReader, WireError, Writer};

/// Upper bound on one record's payload; a larger length prefix is
/// treated as corruption (stops the scan) rather than an allocation.
pub const MAX_WAL_RECORD: usize = 1 << 30;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — hand-rolled because
// the build is offline; table built at compile time.
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32/IEEE checksum (the zlib/PNG polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// One journaled SuperLink state transition. Every mutation of
/// [`crate::flower::superlink::RunState`] has a record here; node
/// registration deliberately does NOT (liveness leases are ephemeral —
/// after recovery nodes re-register via the unknown-node path and keep
/// their pinned ids).
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// A run id was registered on the link.
    RunRegistered { run_id: u64 },
    /// An instruction was queued for `node_id` (carries the full
    /// [`TaskIns`], so recovery can re-queue it verbatim).
    TaskQueued { node_id: u64, ins: TaskIns },
    /// A queued instruction was handed to its node (informational:
    /// recovery re-queues delivered-but-unresolved tasks to the SAME
    /// node, so re-execution is deterministic).
    TaskDelivered { run_id: u64, task_id: u64, node_id: u64 },
    /// Lease expiry moved the task from `from` to `to` (attempt bumped).
    TaskRedelivered {
        run_id: u64,
        task_id: u64,
        from: u64,
        to: u64,
        attempt: u32,
    },
    /// The task was marked failed (assignee unavailable, no redelivery).
    TaskFailed {
        run_id: u64,
        task_id: u64,
        reason: String,
    },
    /// A result entered the done-set. Journaled AFTER the link stamped
    /// the authoritative model version, so replay restores the stamped
    /// result byte-for-byte.
    ResultAccepted { res: TaskRes },
    /// Straggler tasks abandoned at quorum-grace expiry.
    TasksAbandoned { run_id: u64, task_ids: Vec<u64> },
    /// Async driver folded this result into its window (validation
    /// breadcrumb; replay only counts it).
    Folded { run_id: u64, task_id: u64 },
    /// Async driver committed model `version` (validation breadcrumb).
    Committed { run_id: u64, version: u64 },
    /// The run finished and dropped its state.
    RunFinished { run_id: u64 },
}

pub(crate) fn write_task_ins(w: &mut Writer, t: &TaskIns) {
    w.u64(t.task_id);
    w.u64(t.run_id);
    w.u64(t.round);
    write_message_type(w, &t.message_type);
    w.u32(t.attempt);
    w.u8(t.redeliver as u8);
    write_record(w, &t.parameters);
    write_config(w, &t.config);
    w.u64(t.model_version);
}

pub(crate) fn read_task_ins(r: &mut FrameReader) -> Result<TaskIns, WireError> {
    Ok(TaskIns {
        task_id: r.u64()?,
        run_id: r.u64()?,
        round: r.u64()?,
        message_type: read_message_type(r)?,
        attempt: r.u32()?,
        redeliver: r.u8()? != 0,
        parameters: read_record(r)?,
        config: read_config(r)?,
        model_version: r.u64()?,
    })
}

pub(crate) fn write_task_res(w: &mut Writer, t: &TaskRes) {
    w.u64(t.task_id);
    w.u64(t.run_id);
    w.u64(t.node_id);
    w.str(&t.error);
    write_message_type(w, &t.message_type);
    write_record(w, &t.parameters);
    w.u64(t.num_examples);
    w.f64(t.loss);
    write_metrics(w, &t.metrics);
    write_config(w, &t.configs);
    w.u64(t.model_version);
}

pub(crate) fn read_task_res(r: &mut FrameReader) -> Result<TaskRes, WireError> {
    Ok(TaskRes {
        task_id: r.u64()?,
        run_id: r.u64()?,
        node_id: r.u64()?,
        error: r.str()?,
        message_type: read_message_type(r)?,
        parameters: read_record(r)?,
        num_examples: r.u64()?,
        loss: r.f64()?,
        metrics: read_metrics(r)?,
        configs: read_config(r)?,
        model_version: r.u64()?,
    })
}

impl WalRecord {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WalRecord::RunRegistered { run_id } => {
                w.u8(1);
                w.u64(*run_id);
            }
            WalRecord::TaskQueued { node_id, ins } => {
                w.u8(2);
                w.u64(*node_id);
                write_task_ins(&mut w, ins);
            }
            WalRecord::TaskDelivered {
                run_id,
                task_id,
                node_id,
            } => {
                w.u8(3);
                w.u64(*run_id);
                w.u64(*task_id);
                w.u64(*node_id);
            }
            WalRecord::TaskRedelivered {
                run_id,
                task_id,
                from,
                to,
                attempt,
            } => {
                w.u8(4);
                w.u64(*run_id);
                w.u64(*task_id);
                w.u64(*from);
                w.u64(*to);
                w.u32(*attempt);
            }
            WalRecord::TaskFailed {
                run_id,
                task_id,
                reason,
            } => {
                w.u8(5);
                w.u64(*run_id);
                w.u64(*task_id);
                w.str(reason);
            }
            WalRecord::ResultAccepted { res } => {
                w.u8(6);
                write_task_res(&mut w, res);
            }
            WalRecord::TasksAbandoned { run_id, task_ids } => {
                w.u8(7);
                w.u64(*run_id);
                w.u32(task_ids.len() as u32);
                for t in task_ids {
                    w.u64(*t);
                }
            }
            WalRecord::Folded { run_id, task_id } => {
                w.u8(8);
                w.u64(*run_id);
                w.u64(*task_id);
            }
            WalRecord::Committed { run_id, version } => {
                w.u8(9);
                w.u64(*run_id);
                w.u64(*version);
            }
            WalRecord::RunFinished { run_id } => {
                w.u8(10);
                w.u64(*run_id);
            }
        }
        w.into_bytes()
    }

    pub fn decode(payload: Bytes) -> Result<WalRecord, WireError> {
        let mut r = FrameReader::new(payload);
        let rec = match r.u8()? {
            1 => WalRecord::RunRegistered { run_id: r.u64()? },
            2 => WalRecord::TaskQueued {
                node_id: r.u64()?,
                ins: read_task_ins(&mut r)?,
            },
            3 => WalRecord::TaskDelivered {
                run_id: r.u64()?,
                task_id: r.u64()?,
                node_id: r.u64()?,
            },
            4 => WalRecord::TaskRedelivered {
                run_id: r.u64()?,
                task_id: r.u64()?,
                from: r.u64()?,
                to: r.u64()?,
                attempt: r.u32()?,
            },
            5 => WalRecord::TaskFailed {
                run_id: r.u64()?,
                task_id: r.u64()?,
                reason: r.str()?,
            },
            6 => WalRecord::ResultAccepted {
                res: read_task_res(&mut r)?,
            },
            7 => {
                let run_id = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 20 {
                    return Err(WireError::TooLong {
                        len: n,
                        limit: 1 << 20,
                    });
                }
                let mut task_ids = Vec::with_capacity(n);
                for _ in 0..n {
                    task_ids.push(r.u64()?);
                }
                WalRecord::TasksAbandoned { run_id, task_ids }
            }
            8 => WalRecord::Folded {
                run_id: r.u64()?,
                task_id: r.u64()?,
            },
            9 => WalRecord::Committed {
                run_id: r.u64()?,
                version: r.u64()?,
            },
            10 => WalRecord::RunFinished { run_id: r.u64()? },
            t => return Err(WireError::BadTag(t)),
        };
        Ok(rec)
    }

    /// The run this transition belongs to.
    pub fn run_id(&self) -> u64 {
        match self {
            WalRecord::RunRegistered { run_id }
            | WalRecord::TaskDelivered { run_id, .. }
            | WalRecord::TaskRedelivered { run_id, .. }
            | WalRecord::TaskFailed { run_id, .. }
            | WalRecord::TasksAbandoned { run_id, .. }
            | WalRecord::Folded { run_id, .. }
            | WalRecord::Committed { run_id, .. }
            | WalRecord::RunFinished { run_id } => *run_id,
            WalRecord::TaskQueued { ins, .. } => ins.run_id,
            WalRecord::ResultAccepted { res } => res.run_id,
        }
    }
}

// ---------------------------------------------------------------------------
// The log itself
// ---------------------------------------------------------------------------

/// An append-only WAL handle. Not internally synchronized — the
/// SuperLink wraps it in a mutex that is a LEAF in its lock order
/// (runs → wal, never the reverse).
pub struct Wal {
    file: std::fs::File,
    path: PathBuf,
    offset: u64,
}

impl Wal {
    /// Create a FRESH log at `path`, truncating any previous contents.
    pub fn create(path: &Path) -> anyhow::Result<Wal> {
        Wal::open_at(path, 0)
    }

    /// Open `path` (creating it if absent) and continue appending after
    /// byte `offset`, truncating everything past it — this is how
    /// recovery drops a torn tail. `offset` must not exceed the current
    /// file length.
    pub fn open_at(path: &Path, offset: u64) -> anyhow::Result<Wal> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)?;
        let len = file.metadata()?.len();
        anyhow::ensure!(
            offset <= len,
            "WAL {} is {len} bytes, cannot resume at {offset}",
            path.display()
        );
        if len != offset {
            log::warn!(
                "WAL {}: truncating {} torn/stale byte(s) past offset {offset}",
                path.display(),
                len - offset
            );
            file.set_len(offset)?;
        }
        file.seek(SeekFrom::Start(offset))?;
        Ok(Wal {
            file,
            path: path.to_path_buf(),
            offset,
        })
    }

    /// Append one record; returns the file offset after it.
    pub fn append(&mut self, rec: &WalRecord) -> anyhow::Result<u64> {
        let payload = rec.encode();
        let mut frame = Vec::with_capacity(payload.len() + 8);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.offset += frame.len() as u64;
        crate::telemetry::bump("wal.appends", 1);
        crate::telemetry::bump("wal.bytes", frame.len() as i64);
        Ok(self.offset)
    }

    /// Bytes of valid log written so far (== the next append offset).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Result of scanning a WAL tail: the decoded valid prefix.
#[derive(Debug)]
pub struct WalScan {
    pub records: Vec<WalRecord>,
    /// File offset just past the last valid record; recovery truncates
    /// the file here before appending again.
    pub valid_len: u64,
    /// True when bytes past `valid_len` were dropped (torn tail: a
    /// truncated frame, a CRC mismatch, or an undecodable payload).
    pub torn: bool,
}

/// Scan the log at `path` from byte `from`, stopping at the first bad
/// frame. Never panics: a missing file is an empty log, and corruption
/// only shortens the result (no record that fails its CRC is returned).
pub fn scan(path: &Path, from: u64) -> anyhow::Result<WalScan> {
    let data = match std::fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalScan {
                records: Vec::new(),
                valid_len: from,
                torn: false,
            })
        }
        Err(e) => return Err(e.into()),
    };
    anyhow::ensure!(
        from as usize <= data.len(),
        "WAL {} is {} bytes but the checkpoint claims offset {from} — mismatched files?",
        path.display(),
        data.len()
    );
    let shared = Bytes::from_vec(data);
    let total = shared.len();
    let mut pos = from as usize;
    let mut records = Vec::new();
    let mut torn = false;
    while pos < total {
        if pos + 8 > total {
            torn = true;
            break;
        }
        let head = shared.as_slice();
        let len = u32::from_le_bytes([head[pos], head[pos + 1], head[pos + 2], head[pos + 3]])
            as usize;
        let want = u32::from_le_bytes([
            head[pos + 4],
            head[pos + 5],
            head[pos + 6],
            head[pos + 7],
        ]);
        if len > MAX_WAL_RECORD || pos + 8 + len > total {
            torn = true;
            break;
        }
        let payload = shared.slice(pos + 8, len);
        if crc32(payload.as_slice()) != want {
            torn = true;
            break;
        }
        match WalRecord::decode(payload) {
            Ok(rec) => {
                records.push(rec);
                pos += 8 + len;
            }
            Err(e) => {
                // CRC passed but the payload is gibberish (e.g. written
                // by a different version): treat as end-of-valid-log.
                log::warn!("WAL {}: undecodable record at {pos}: {e}", path.display());
                torn = true;
                break;
            }
        }
    }
    if torn {
        log::warn!(
            "WAL {}: dropped {} torn byte(s) after offset {pos}",
            path.display(),
            total - pos
        );
        crate::telemetry::bump("wal.torn_tails", 1);
    }
    Ok(WalScan {
        records,
        valid_len: pos as u64,
        torn,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::message::MessageType;
    use crate::flower::persist::test_dir;
    use crate::flower::records::ArrayRecord;
    use crate::util::rng::Rng;

    fn sample_records() -> Vec<WalRecord> {
        let ins = TaskIns {
            task_id: 7,
            run_id: 1,
            round: 2,
            message_type: MessageType::Train,
            attempt: 0,
            redeliver: false,
            model_version: 3,
            parameters: ArrayRecord::from_flat(&[1.0, -2.5, 0.0]),
            config: Default::default(),
        };
        let res = TaskRes {
            task_id: 7,
            run_id: 1,
            node_id: 4,
            error: String::new(),
            message_type: MessageType::Train,
            parameters: ArrayRecord::from_flat(&[0.5; 3]),
            num_examples: 10,
            loss: 0.0,
            metrics: Default::default(),
            configs: Default::default(),
            model_version: 3,
        };
        vec![
            WalRecord::RunRegistered { run_id: 1 },
            WalRecord::TaskQueued { node_id: 4, ins },
            WalRecord::TaskDelivered {
                run_id: 1,
                task_id: 7,
                node_id: 4,
            },
            WalRecord::TaskRedelivered {
                run_id: 1,
                task_id: 7,
                from: 4,
                to: 5,
                attempt: 1,
            },
            WalRecord::ResultAccepted { res },
            WalRecord::TaskFailed {
                run_id: 1,
                task_id: 9,
                reason: "node 5 unavailable".into(),
            },
            WalRecord::TasksAbandoned {
                run_id: 1,
                task_ids: vec![11, 12],
            },
            WalRecord::Folded {
                run_id: 1,
                task_id: 7,
            },
            WalRecord::Committed { run_id: 1, version: 1 },
            WalRecord::RunFinished { run_id: 1 },
        ]
    }

    fn write_log(path: &std::path::Path, recs: &[WalRecord]) {
        let mut wal = Wal::create(path).unwrap();
        for r in recs {
            wal.append(r).unwrap();
        }
    }

    #[test]
    fn crc32_check_value() {
        // The canonical CRC-32/IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_all_record_kinds() {
        let dir = test_dir("wal-roundtrip");
        let path = dir.join("superlink.wal");
        let recs = sample_records();
        write_log(&path, &recs);
        let scanned = scan(&path, 0).unwrap();
        assert!(!scanned.torn);
        assert_eq!(scanned.records, recs);
        assert_eq!(
            scanned.valid_len,
            std::fs::metadata(&path).unwrap().len()
        );
        assert!(scanned.records.iter().all(|r| r.run_id() == 1));
    }

    #[test]
    fn truncated_tail_is_dropped_not_replayed() {
        let dir = test_dir("wal-truncate");
        let path = dir.join("superlink.wal");
        let recs = sample_records();
        write_log(&path, &recs);
        let full = std::fs::read(&path).unwrap();
        // Chop bytes off the end one frame's worth of positions and make
        // sure the scan never panics and only ever returns a true prefix.
        for cut in 1..=24usize {
            let keep = full.len().saturating_sub(cut);
            std::fs::write(&path, &full[..keep]).unwrap();
            let scanned = scan(&path, 0).unwrap();
            assert!(scanned.records.len() < recs.len());
            assert_eq!(scanned.records[..], recs[..scanned.records.len()]);
            assert!(scanned.valid_len <= keep as u64);
        }
    }

    #[test]
    fn bit_flip_detected_by_crc() {
        let dir = test_dir("wal-bitflip");
        let path = dir.join("superlink.wal");
        let recs = sample_records();
        write_log(&path, &recs);
        let full = std::fs::read(&path).unwrap();
        // Flip one bit in the LAST frame's payload: the scan must drop
        // exactly that record and keep everything before it.
        let mut damaged = full.clone();
        let last = damaged.len() - 3;
        damaged[last] ^= 0x40;
        std::fs::write(&path, &damaged).unwrap();
        let scanned = scan(&path, 0).unwrap();
        assert!(scanned.torn);
        assert_eq!(scanned.records.len(), recs.len() - 1);
        assert_eq!(scanned.records[..], recs[..recs.len() - 1]);
        // Reopening at the valid prefix truncates the damage away.
        let wal = Wal::open_at(&path, scanned.valid_len).unwrap();
        assert_eq!(wal.offset(), scanned.valid_len);
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            scanned.valid_len
        );
    }

    #[test]
    fn missing_file_is_empty_log() {
        let dir = test_dir("wal-missing");
        let scanned = scan(&dir.join("nope.wal"), 0).unwrap();
        assert!(scanned.records.is_empty());
        assert!(!scanned.torn);
    }

    /// Reproducible torn-write fuzzing: WAL_FUZZ_SEED=<n> reruns a
    /// failing corruption pattern from CI logs (CHAOS_SEED convention).
    #[test]
    fn fuzz_corruption_never_panics_never_replays_garbage() {
        let seed = std::env::var("WAL_FUZZ_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xF1AE_5EED_u64);
        println!("WAL_FUZZ_SEED={seed}");
        let mut rng = Rng::new(seed);
        let dir = test_dir("wal-fuzz");
        let path = dir.join("superlink.wal");
        let mut recs = Vec::new();
        for _ in 0..4 {
            recs.extend(sample_records());
        }
        write_log(&path, &recs);
        let pristine = std::fs::read(&path).unwrap();
        let encoded: Vec<Vec<u8>> = recs.iter().map(|r| r.encode()).collect();
        for _ in 0..200 {
            let mut damaged = pristine.clone();
            // Random truncation, then a few random bit flips.
            let keep = (rng.next_u64() as usize) % (damaged.len() + 1);
            damaged.truncate(keep);
            for _ in 0..(rng.next_u64() % 4) {
                if damaged.is_empty() {
                    break;
                }
                let at = (rng.next_u64() as usize) % damaged.len();
                damaged[at] ^= 1 << (rng.next_u64() % 8);
            }
            std::fs::write(&path, &damaged).unwrap();
            let scanned = scan(&path, 0).unwrap();
            // Whatever survived must decode to SOME valid record — and
            // valid_len must point at a frame boundary we can reopen at.
            assert!(scanned.records.len() <= recs.len());
            for rec in &scanned.records {
                // Every surviving record is byte-identical to one we
                // actually wrote (CRC makes forgery vanishingly
                // unlikely; this catches aliasing bugs in the scanner).
                assert!(encoded.contains(&rec.encode()));
            }
            assert!(scanned.valid_len <= damaged.len() as u64);
            Wal::open_at(&path, scanned.valid_len).unwrap();
        }
    }
}
