//! Replay: fold the last checkpoint plus the WAL tail back into run
//! state a fresh [`crate::flower::superlink::SuperLink`] can adopt.
//!
//! The algorithm is pure (files in, [`RecoveredState`] out): seed
//! per-run working state from the checkpoint, apply every WAL record
//! past the checkpoint's offset in order, then canonicalize. Tasks
//! that were delivered but unresolved at the crash are re-queued as
//! pending for their ORIGINAL node — with deterministic clients,
//! re-executing on the same node reproduces the same result bits,
//! which is what makes recovery exact rather than approximate.
//!
//! Claims are deliberately not journaled: a result handed to a driver
//! that died before folding it is replayed back into the recovered
//! link and simply claimed again. Together with the link's done-set
//! (duplicate accepts are dropped) every result is folded exactly
//! once across a crash.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use super::checkpoint::{Checkpoint, RunSnapshot};
use super::wal::{self, WalRecord};
use super::{CKPT_FILE, WAL_FILE};
use crate::flower::message::{TaskIns, TaskRes};

/// Everything `SuperLink::recover` needs to resume: canonical run
/// snapshots (in-flight work re-queued as pending), id counters, the
/// drivers' opaque resume blobs, and where the valid WAL ends.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveredState {
    pub next_node: u64,
    pub next_task: u64,
    pub runs: Vec<RunSnapshot>,
    pub drivers: Vec<(u64, Vec<u8>)>,
    /// Byte length of the valid WAL prefix; the recovered link keeps
    /// appending from here (a torn suffix is truncated away).
    pub wal_valid_len: u64,
    /// True when the WAL ended in a truncated or CRC-damaged record.
    pub torn: bool,
    /// Records replayed past the checkpoint.
    pub replayed: u64,
}

/// Mutable per-run working state during replay.
#[derive(Default)]
struct Working {
    active: bool,
    /// Queued-or-delivered, unresolved tasks: task id -> (assigned
    /// node, attempt, instruction if retained).
    unresolved: BTreeMap<u64, (u64, u32, Option<TaskIns>)>,
    results: BTreeMap<u64, TaskRes>,
    failed: BTreeMap<u64, String>,
    done: BTreeSet<u64>,
    task_version: BTreeMap<u64, u64>,
    acked: BTreeSet<u64>,
}

impl Working {
    fn from_snapshot(snap: &RunSnapshot) -> Working {
        let mut w = Working {
            active: snap.active,
            ..Default::default()
        };
        for (node, list) in &snap.pending {
            for ins in list {
                w.unresolved
                    .insert(ins.task_id, (*node, ins.attempt, Some(ins.clone())));
            }
        }
        for t in &snap.inflight {
            w.unresolved
                .insert(t.task_id, (t.node_id, t.attempt, t.ins.clone()));
        }
        for res in &snap.results {
            w.results.insert(res.task_id, res.clone());
        }
        w.failed.extend(snap.failed.iter().cloned());
        w.done.extend(snap.done.iter().copied());
        w.task_version.extend(snap.task_version.iter().copied());
        w.acked.extend(snap.acked.iter().copied());
        w
    }

    fn resolve(&mut self, task_id: u64) {
        self.unresolved.remove(&task_id);
        self.task_version.remove(&task_id);
    }

    /// Canonical snapshot: unresolved work becomes pending for its
    /// original node; instructions lost across recovery (journaled
    /// without a retained payload) fail typed instead of hanging.
    fn into_snapshot(mut self, run_id: u64) -> RunSnapshot {
        let mut pending: BTreeMap<u64, Vec<TaskIns>> = BTreeMap::new();
        for (task_id, (node, _attempt, ins)) in std::mem::take(&mut self.unresolved) {
            match ins {
                Some(ins) => pending.entry(node).or_default().push(ins),
                None => {
                    self.done.insert(task_id);
                    self.failed
                        .insert(task_id, "instruction lost across recovery".into());
                    self.task_version.remove(&task_id);
                }
            }
        }
        RunSnapshot {
            run_id,
            active: self.active,
            pending: pending.into_iter().collect(),
            inflight: Vec::new(),
            results: self.results.into_values().collect(),
            failed: self.failed.into_iter().collect(),
            done: self.done.into_iter().collect(),
            task_version: self.task_version.into_iter().collect(),
            acked: self.acked.into_iter().collect(),
        }
    }
}

/// Load `<dir>/superlink.ckpt` + `<dir>/superlink.wal` and replay.
/// Never panics on damaged input: a corrupt checkpoint is ignored
/// (full-WAL replay instead), a torn WAL tail is dropped.
pub fn load(dir: &Path) -> RecoveredState {
    let ckpt = Checkpoint::read(&dir.join(CKPT_FILE)).unwrap_or_default();
    let wal_path = dir.join(WAL_FILE);
    let scan = match wal::scan(&wal_path, ckpt.wal_offset) {
        Ok(s) => s,
        Err(e) => {
            // Unreadable log, or a file shorter than the checkpoint's
            // recorded offset (mismatched/rolled-back files). The
            // checkpoint alone is still a consistent cut: recover from
            // it and treat the whole tail as torn.
            log::warn!("WAL scan failed ({e}); recovering from checkpoint alone");
            let len = std::fs::metadata(&wal_path).map(|m| m.len()).unwrap_or(0);
            wal::WalScan {
                records: Vec::new(),
                valid_len: len.min(ckpt.wal_offset),
                torn: true,
            }
        }
    };

    let mut next_node = ckpt.next_node;
    let mut next_task = ckpt.next_task;
    let mut runs: BTreeMap<u64, Working> = ckpt
        .runs
        .iter()
        .map(|snap| (snap.run_id, Working::from_snapshot(snap)))
        .collect();

    let replayed = scan.records.len() as u64;
    for rec in scan.records {
        match rec {
            WalRecord::RunRegistered { run_id } => {
                runs.entry(run_id).or_default().active = true;
            }
            WalRecord::TaskQueued { node_id, ins } => {
                next_task = next_task.max(ins.task_id + 1);
                next_node = next_node.max(node_id + 1);
                let w = runs.entry(ins.run_id).or_default();
                w.task_version.insert(ins.task_id, ins.model_version);
                w.unresolved
                    .insert(ins.task_id, (node_id, ins.attempt, Some(ins)));
            }
            WalRecord::TaskDelivered { .. } => {}
            WalRecord::TaskRedelivered {
                run_id,
                task_id,
                to,
                attempt,
                ..
            } => {
                next_node = next_node.max(to + 1);
                if let Some(w) = runs.get_mut(&run_id) {
                    if let Some(entry) = w.unresolved.get_mut(&task_id) {
                        entry.0 = to;
                        entry.1 = attempt;
                        if let Some(ins) = entry.2.as_mut() {
                            ins.attempt = attempt;
                        }
                    }
                }
            }
            WalRecord::TaskFailed {
                run_id,
                task_id,
                reason,
            } => {
                if let Some(w) = runs.get_mut(&run_id) {
                    w.done.insert(task_id);
                    w.failed.insert(task_id, reason);
                    w.resolve(task_id);
                }
            }
            WalRecord::ResultAccepted { res } => {
                next_node = next_node.max(res.node_id + 1);
                let w = runs.entry(res.run_id).or_default();
                let task_id = res.task_id;
                if w.done.insert(task_id) {
                    w.results.insert(task_id, res);
                }
                w.resolve(task_id);
            }
            WalRecord::TasksAbandoned { run_id, task_ids } => {
                if let Some(w) = runs.get_mut(&run_id) {
                    for task_id in task_ids {
                        w.done.insert(task_id);
                        w.resolve(task_id);
                    }
                }
            }
            WalRecord::Folded { .. } | WalRecord::Committed { .. } => {}
            WalRecord::RunFinished { run_id } => {
                if let Some(w) = runs.get_mut(&run_id) {
                    w.active = false;
                    w.unresolved.clear();
                    w.results.clear();
                    w.failed.clear();
                    w.done.clear();
                    w.task_version.clear();
                }
            }
        }
    }

    crate::telemetry::bump("recovery.replayed_records", replayed as i64);
    RecoveredState {
        next_node,
        next_task,
        runs: runs
            .into_iter()
            .map(|(run_id, w)| w.into_snapshot(run_id))
            .collect(),
        drivers: ckpt.drivers,
        wal_valid_len: scan.valid_len,
        torn: scan.torn,
        replayed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::message::MessageType;
    use crate::flower::persist::test_dir;
    use crate::flower::persist::wal::Wal;
    use crate::flower::records::ArrayRecord;

    fn ins(run_id: u64, task_id: u64, version: u64) -> TaskIns {
        TaskIns {
            task_id,
            run_id,
            round: 1,
            message_type: MessageType::Train,
            attempt: 0,
            redeliver: false,
            model_version: version,
            parameters: ArrayRecord::from_flat(&[0.5; 2]),
            config: Default::default(),
        }
    }

    fn res(run_id: u64, task_id: u64, node_id: u64) -> TaskRes {
        TaskRes {
            task_id,
            run_id,
            node_id,
            error: String::new(),
            message_type: MessageType::Train,
            parameters: ArrayRecord::from_flat(&[1.0; 2]),
            num_examples: 4,
            loss: 0.0,
            metrics: Default::default(),
            configs: Default::default(),
            model_version: 1,
        }
    }

    #[test]
    fn replay_without_checkpoint_rebuilds_run() {
        let dir = test_dir("rec-no-ckpt");
        let mut wal = Wal::create(&dir.join(WAL_FILE)).unwrap();
        wal.append(&WalRecord::RunRegistered { run_id: 1 }).unwrap();
        wal.append(&WalRecord::TaskQueued {
            node_id: 1,
            ins: ins(1, 10, 1),
        })
        .unwrap();
        wal.append(&WalRecord::TaskQueued {
            node_id: 2,
            ins: ins(1, 11, 1),
        })
        .unwrap();
        wal.append(&WalRecord::TaskDelivered {
            run_id: 1,
            task_id: 10,
            node_id: 1,
        })
        .unwrap();
        wal.append(&WalRecord::ResultAccepted { res: res(1, 10, 1) })
            .unwrap();
        wal.append(&WalRecord::TaskFailed {
            run_id: 1,
            task_id: 11,
            reason: "lease expired".into(),
        })
        .unwrap();
        drop(wal);

        let state = load(&dir);
        assert_eq!(state.replayed, 6);
        assert!(!state.torn);
        assert_eq!(state.next_task, 12);
        assert_eq!(state.next_node, 3);
        assert_eq!(state.runs.len(), 1);
        let run = &state.runs[0];
        assert!(run.active);
        assert!(run.pending.is_empty(), "both tasks resolved");
        assert!(run.inflight.is_empty());
        assert_eq!(run.results.len(), 1);
        assert_eq!(run.results[0].task_id, 10);
        assert_eq!(run.failed, vec![(11, "lease expired".into())]);
        assert_eq!(run.done, vec![10, 11]);
        assert!(run.task_version.is_empty());
    }

    #[test]
    fn unresolved_tasks_requeue_to_original_node() {
        let dir = test_dir("rec-requeue");
        let mut wal = Wal::create(&dir.join(WAL_FILE)).unwrap();
        wal.append(&WalRecord::RunRegistered { run_id: 1 }).unwrap();
        wal.append(&WalRecord::TaskQueued {
            node_id: 2,
            ins: ins(1, 5, 3),
        })
        .unwrap();
        wal.append(&WalRecord::TaskDelivered {
            run_id: 1,
            task_id: 5,
            node_id: 2,
        })
        .unwrap();
        wal.append(&WalRecord::TaskRedelivered {
            run_id: 1,
            task_id: 5,
            from: 2,
            to: 4,
            attempt: 1,
        })
        .unwrap();
        drop(wal);

        let run = &load(&dir).runs[0];
        assert_eq!(run.pending.len(), 1);
        let (node, list) = &run.pending[0];
        assert_eq!(*node, 4, "re-queued to last assignee");
        assert_eq!(list[0].task_id, 5);
        assert_eq!(list[0].attempt, 1);
        assert_eq!(run.task_version, vec![(5, 3)]);
    }

    #[test]
    fn checkpoint_plus_tail_and_duplicate_accepts() {
        let dir = test_dir("rec-ckpt-tail");
        let mut wal = Wal::create(&dir.join(WAL_FILE)).unwrap();
        wal.append(&WalRecord::RunRegistered { run_id: 1 }).unwrap();
        wal.append(&WalRecord::TaskQueued {
            node_id: 1,
            ins: ins(1, 7, 2),
        })
        .unwrap();
        let cut = wal.offset();
        // Checkpoint cut here: the snapshot carries the queued task.
        let mut snap = RunSnapshot {
            run_id: 1,
            active: true,
            ..Default::default()
        };
        snap.pending.push((1, vec![ins(1, 7, 2)]));
        snap.task_version.push((7, 2));
        let ckpt = Checkpoint {
            wal_offset: cut,
            next_node: 2,
            next_task: 8,
            runs: vec![snap],
            drivers: vec![(1, vec![1, 2, 3])],
        };
        ckpt.write(&dir.join(CKPT_FILE)).unwrap();
        // Tail past the checkpoint: the result arrives twice (a
        // redelivery raced the original); only the first is kept.
        wal.append(&WalRecord::ResultAccepted { res: res(1, 7, 3) })
            .unwrap();
        let mut dup = res(1, 7, 9);
        dup.num_examples = 99;
        wal.append(&WalRecord::ResultAccepted { res: dup }).unwrap();
        drop(wal);

        let state = load(&dir);
        assert_eq!(state.replayed, 2, "only the tail replays");
        assert_eq!(state.drivers, vec![(1, vec![1, 2, 3])]);
        let run = &state.runs[0];
        assert_eq!(run.results.len(), 1);
        assert_eq!(run.results[0].node_id, 3, "first accept wins");
        assert!(run.pending.is_empty());
    }

    #[test]
    fn torn_tail_is_dropped_and_finished_runs_clear() {
        let dir = test_dir("rec-torn");
        let path = dir.join(WAL_FILE);
        let mut wal = Wal::create(&path).unwrap();
        wal.append(&WalRecord::RunRegistered { run_id: 1 }).unwrap();
        wal.append(&WalRecord::RunFinished { run_id: 1 }).unwrap();
        let good = wal.offset();
        wal.append(&WalRecord::RunRegistered { run_id: 2 }).unwrap();
        drop(wal);
        // Tear the last record.
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(wal_len_minus(&path, 3)).unwrap();
        drop(f);

        let state = load(&dir);
        assert!(state.torn);
        assert_eq!(state.wal_valid_len, good);
        assert_eq!(state.runs.len(), 1, "torn register never replayed");
        assert!(!state.runs[0].active, "finished run is inactive");
        assert!(state.runs[0].done.is_empty());
    }

    fn wal_len_minus(path: &std::path::Path, cut: u64) -> u64 {
        std::fs::metadata(path).unwrap().len() - cut
    }
}
