//! Client-side differential privacy (the paper's §1 names "rich built-in
//! differential privacy" as a Flower capability FLARE users gain): the
//! classic DP-FedAvg client recipe — clip the model delta's L2 norm to
//! `clip`, add Gaussian noise `N(0, (noise_multiplier * clip)^2)` per
//! coordinate — packaged as a [`ClientMod`] so any app becomes
//! differentially private without modification.
//!
//! The delta, clip, and noise are computed **per tensor in record
//! order** over the update's [`ArrayRecord`]; the L2 norm is the global
//! norm across all tensors (the classic recipe), so the result is
//! bit-identical to clipping the flat concatenation. Only float tensors
//! can carry noise — non-float dtypes are rejected loudly rather than
//! silently leaking.
//!
//! Noise is seeded from (dp_seed, node_id, round) — deterministic per
//! task, so the Fig. 5 transport-independence property still holds for
//! DP runs (the same noise is drawn on both paths).

use crate::flower::clientapp::FitOutput;
use crate::flower::message::ConfigRecord;
use crate::flower::mods::{ClientMod, FitNext};
use crate::flower::records::{ArrayRecord, DType, Tensor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DpConfig {
    /// L2 clipping bound for the per-round client delta.
    pub clip: f64,
    /// Noise stddev as a multiple of the clip bound (sigma = z * clip).
    pub noise_multiplier: f64,
    /// Base seed for the per-(node, round) noise stream.
    pub seed: u64,
    /// Target delta for the epsilon report.
    pub delta: f64,
}

impl Default for DpConfig {
    fn default() -> Self {
        Self {
            clip: 1.0,
            noise_multiplier: 1.0,
            seed: 0xD9,
            delta: 1e-5,
        }
    }
}

impl DpConfig {
    /// Per-round epsilon of the Gaussian mechanism (classic bound,
    /// valid for z >= ~0.5; rounds compose additively here — a moments
    /// accountant would be tighter).
    pub fn epsilon_per_round(&self) -> f64 {
        (2.0 * (1.25 / self.delta).ln()).sqrt() / self.noise_multiplier
    }
}

pub struct DpMod {
    pub cfg: DpConfig,
}

impl DpMod {
    pub fn new(cfg: DpConfig) -> Self {
        Self { cfg }
    }
}

impl ClientMod for DpMod {
    fn name(&self) -> &'static str {
        "dp"
    }

    fn on_fit(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
        next: FitNext,
    ) -> anyhow::Result<FitOutput> {
        let mut out = next(parameters, config)?;
        anyhow::ensure!(
            out.parameters.dims_match(parameters),
            "dp: inner app changed the record structure"
        );
        for t in parameters.tensors() {
            anyhow::ensure!(
                matches!(t.dtype(), DType::F32 | DType::F64),
                "dp: tensor '{}' is {}, only float tensors can carry noise",
                t.name(),
                t.dtype().name()
            );
        }
        let round = config.get_f64("round").unwrap_or(0.0) as u64;
        let node = config.get_i64("node_id").unwrap_or(0) as u64;

        // Per-tensor deltas; global L2 across the whole record.
        let mut deltas: Vec<Vec<f64>> = Vec::with_capacity(parameters.len());
        let mut l2_sq = 0f64;
        for (base, upd) in parameters.tensors().iter().zip(out.parameters.tensors()) {
            let d: Vec<f64> = (0..base.elems())
                .map(|i| upd.get_f64(i) - base.get_f64(i))
                .collect();
            l2_sq += d.iter().map(|x| x * x).sum::<f64>();
            deltas.push(d);
        }
        let l2 = l2_sq.sqrt();
        let scale = if l2 > self.cfg.clip {
            self.cfg.clip / l2
        } else {
            1.0
        };
        if scale < 1.0 {
            crate::telemetry::bump("dp.clipped", 1);
        }

        // Noise (deterministic per node+round), one stream across
        // tensors in record order.
        let mut rng = Rng::new(self.cfg.seed)
            .split(node)
            .split(round.wrapping_add(1));
        let sigma = self.cfg.noise_multiplier * self.cfg.clip;
        let mut tensors = Vec::with_capacity(parameters.len());
        for (base, d) in parameters.tensors().iter().zip(deltas) {
            tensors.push(Tensor::from_f64_values(
                base.name(),
                base.dtype(),
                base.shape().to_vec(),
                (0..base.elems())
                    .map(|i| base.get_f64(i) + d[i] * scale + sigma * rng.normal())
                    .collect::<Vec<f64>>()
                    .into_iter(),
            ));
        }
        out.parameters = ArrayRecord::from_tensors(tensors)?;

        out.metrics
            .push(("dp_epsilon_round".into(), self.cfg.epsilon_per_round()));
        out.metrics.push(("dp_clip_scale".into(), scale));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::clientapp::{ArithmeticClient, ClientApp};
    use crate::flower::message::ConfigValue;
    use crate::flower::mods::ModStack;
    use std::sync::Arc;

    fn cfg_round(round: i64, node: i64) -> ConfigRecord {
        ConfigRecord::from_pairs(vec![
            ("round".into(), ConfigValue::I64(round)),
            ("node_id".into(), ConfigValue::I64(node)),
        ])
    }

    fn dp_app(clip: f64, z: f64) -> ModStack {
        ModStack::new(
            Arc::new(ArithmeticClient { delta: 1.0, n: 4 }),
            vec![Arc::new(DpMod::new(DpConfig {
                clip,
                noise_multiplier: z,
                ..Default::default()
            }))],
        )
    }

    fn flat(v: &[f32]) -> ArrayRecord {
        ArrayRecord::from_flat(v)
    }

    #[test]
    fn zero_noise_large_clip_is_transparent() {
        let app = dp_app(1e9, 0.0);
        let out = app.fit(&flat(&[1.0, 2.0]), &cfg_round(1, 1)).unwrap();
        // sigma = 0, no clip: exact inner result.
        assert_eq!(out.parameters.to_flat(), vec![2.0, 3.0]);
    }

    #[test]
    fn clipping_bounds_delta_norm() {
        // Inner delta = (1,1,1,1), l2 = 2; clip to 1.0 -> delta 0.5 each.
        let app = dp_app(1.0, 0.0);
        let out = app.fit(&flat(&[0.0; 4]), &cfg_round(1, 1)).unwrap();
        let l2: f64 = out
            .parameters
            .to_flat()
            .iter()
            .map(|p| (*p as f64) * (*p as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - 1.0).abs() < 1e-6, "clipped l2 = {l2}");
        let scale = out
            .metrics
            .iter()
            .find(|(k, _)| k == "dp_clip_scale")
            .unwrap()
            .1;
        assert!((scale - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clipping_uses_global_norm_across_tensors() {
        // Two tensors, combined delta (1,1,1,1) -> same global clip as
        // the flat case; per-tensor structure preserved.
        let rec = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("a", vec![2], &[0.0, 0.0]),
            Tensor::from_f32("b", vec![2], &[0.0, 0.0]),
        ])
        .unwrap();
        let app = dp_app(1.0, 0.0);
        let out = app.fit(&rec, &cfg_round(1, 1)).unwrap();
        assert!(out.parameters.dims_match(&rec));
        let l2: f64 = out
            .parameters
            .to_flat()
            .iter()
            .map(|p| (*p as f64) * (*p as f64))
            .sum::<f64>()
            .sqrt();
        assert!((l2 - 1.0).abs() < 1e-6, "global l2 = {l2}");
    }

    #[test]
    fn non_float_tensors_rejected() {
        let rec = ArrayRecord::from_tensors(vec![Tensor::from_i64("steps", vec![1], &[3])])
            .unwrap();
        let app = dp_app(1.0, 1.0);
        let err = app.fit(&rec, &cfg_round(1, 1)).unwrap_err();
        assert!(err.to_string().contains("float"), "{err}");
    }

    #[test]
    fn noise_is_deterministic_per_node_round() {
        let app = dp_app(1.0, 1.0);
        let a = app.fit(&flat(&[0.0; 8]), &cfg_round(3, 2)).unwrap();
        let b = app.fit(&flat(&[0.0; 8]), &cfg_round(3, 2)).unwrap();
        assert!(a.parameters.bits_equal(&b.parameters));
        let c = app.fit(&flat(&[0.0; 8]), &cfg_round(4, 2)).unwrap();
        assert!(!a.parameters.bits_equal(&c.parameters), "round must vary noise");
        let d = app.fit(&flat(&[0.0; 8]), &cfg_round(3, 3)).unwrap();
        assert!(!a.parameters.bits_equal(&d.parameters), "node must vary noise");
    }

    #[test]
    fn noise_scale_matches_sigma() {
        let app = dp_app(1.0, 2.0); // sigma = 2
        let n = 4000;
        let out = app.fit(&flat(&vec![0.0; n]), &cfg_round(1, 1)).unwrap();
        // delta per coord = 1/sqrt(n)*... inner delta (1,...) clipped to
        // l2=1 -> per-coord 1/sqrt(n) ~ 0.016, negligible vs noise.
        let params = out.parameters.to_flat();
        let mean: f64 = params.iter().map(|p| *p as f64).sum::<f64>() / n as f64;
        let var: f64 = params
            .iter()
            .map(|p| (*p as f64 - mean).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((var.sqrt() - 2.0).abs() < 0.15, "std {}", var.sqrt());
    }

    #[test]
    fn epsilon_reporting() {
        let cfg = DpConfig {
            noise_multiplier: 1.0,
            delta: 1e-5,
            ..Default::default()
        };
        let eps = cfg.epsilon_per_round();
        assert!((eps - (2.0f64 * (1.25e5f64).ln()).sqrt()).abs() < 1e-9);
        // Stronger noise, smaller epsilon.
        let strong = DpConfig {
            noise_multiplier: 4.0,
            ..cfg
        };
        assert!(strong.epsilon_per_round() < eps);
    }
}
