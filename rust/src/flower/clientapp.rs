//! Flower ClientApp: user code run by a SuperNode (paper Listing 2's
//! `NumPyClient` analogue). Implementations receive the global model as
//! an [`ArrayRecord`] of named, dtyped tensors plus a config record and
//! return updated parameters / evaluation results.

use crate::flower::message::{ConfigRecord, MetricRecord};
use crate::flower::records::ArrayRecord;

/// Result of a local `fit` (train) call.
#[derive(Clone, Debug)]
pub struct FitOutput {
    pub parameters: ArrayRecord,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

/// Result of a local `evaluate` call.
#[derive(Clone, Debug)]
pub struct EvalOutput {
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

/// The NumPyClient-style interface (paper Listing 2: `fit`/`evaluate`).
pub trait ClientApp: Send + Sync {
    fn fit(&self, parameters: &ArrayRecord, config: &ConfigRecord) -> anyhow::Result<FitOutput>;
    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput>;
}

/// Deterministic toy client used across tests: `fit` adds `delta` to
/// every element of every tensor (per-tensor, preserving names, shapes,
/// and dtypes) and reports `n` examples; `evaluate` returns the mean of
/// all elements as "loss".
pub struct ArithmeticClient {
    pub delta: f32,
    pub n: u64,
}

impl ClientApp for ArithmeticClient {
    fn fit(&self, parameters: &ArrayRecord, _config: &ConfigRecord) -> anyhow::Result<FitOutput> {
        let delta = self.delta as f64;
        Ok(FitOutput {
            parameters: parameters.map_f64(|_, _, v| v + delta),
            num_examples: self.n,
            metrics: vec![("train_loss".into(), self.delta as f64)],
        })
    }

    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        _config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput> {
        let n = parameters.total_elems();
        let mut sum = 0.0f64;
        for t in parameters.tensors() {
            for i in 0..t.elems() {
                sum += t.get_f64(i);
            }
        }
        let mean = sum / n.max(1) as f64;
        Ok(EvalOutput {
            loss: mean,
            num_examples: self.n,
            metrics: vec![("accuracy".into(), 1.0 - mean.abs().min(1.0))],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::records::Tensor;

    #[test]
    fn arithmetic_client_behaviour() {
        let c = ArithmeticClient { delta: 0.5, n: 8 };
        let fit = c.fit(&ArrayRecord::from_flat(&[1.0, 2.0]), &vec![]).unwrap();
        assert_eq!(fit.parameters.to_flat(), vec![1.5, 2.5]);
        assert_eq!(fit.num_examples, 8);
        let ev = c
            .evaluate(&ArrayRecord::from_flat(&[1.0, 3.0]), &vec![])
            .unwrap();
        assert!((ev.loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn arithmetic_client_preserves_multi_tensor_structure() {
        let rec = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2], &[1.0, 2.0]),
            Tensor::from_i64("steps", vec![2], &[10, 20]),
        ])
        .unwrap();
        let c = ArithmeticClient { delta: 1.0, n: 1 };
        let out = c.fit(&rec, &vec![]).unwrap();
        assert!(out.parameters.dims_match(&rec));
        assert_eq!(out.parameters.get("w").unwrap().get_f64(0), 2.0);
        assert_eq!(out.parameters.get("steps").unwrap().get_f64(1), 21.0);
    }
}
