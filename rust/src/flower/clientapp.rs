//! The node-side app boundary: typed message handlers.
//!
//! A SuperNode executes [`Message`]s through a [`MessageApp`] — in
//! practice a [`Router`]: a registry of per-[`MessageType`] handlers
//! ([`Router::on_train`] / [`Router::on_evaluate`] / [`Router::on_query`]
//! plus [`Router::on`] for custom verbs). Every handler receives the
//! message AND a persistent per-run [`Context`] whose
//! [`StateRecord`](crate::flower::records::StateRecord) survives across
//! rounds on the SuperNode — stateful clients, personalization, and warm
//! optimizer state without any wire traffic.
//!
//! The classic fit/evaluate [`ClientApp`] trait (paper Listing 2's
//! `NumPyClient` analogue) is still the convenient way to write an FL
//! client; [`Router::from_client`] is the blanket adapter that mounts it
//! as `Train`/`Evaluate` handlers — byte-identical to the pre-registry
//! dispatch, which is what keeps every strategy, mod, and conformance
//! row unchanged.

use std::sync::Arc;

use crate::flower::message::{ConfigRecord, Message, MessageType, MetricRecord};
use crate::flower::records::{
    ArrayRecord, RecordDict, StateRecord, WireCodec, UNSUPPORTED_CODEC_ERR, WIRE_CODEC_KEY,
};

/// Marker carried in the error reply when a node receives a message
/// type it has no handler for (see [`Router`]). The driver surfaces the
/// reply per node instead of the node panicking or silently dropping
/// the task; [`is_unhandled`] recognizes it.
pub const UNHANDLED_MESSAGE_ERR: &str = "unhandled message type";

/// Does this (per-node) error string report a missing handler?
pub fn is_unhandled(error: &str) -> bool {
    error.contains(UNHANDLED_MESSAGE_ERR)
}

/// Per-run, per-node execution context. Created by the SuperNode the
/// first time a run's message reaches the node and **kept across
/// rounds**: whatever a handler writes into `state` in round N is there
/// in round N+1. State is scoped per run id — two concurrent runs never
/// see each other's state — and never leaves the node. Retained
/// contexts are LRU-bounded by
/// [`SuperNodeConfig::max_run_contexts`](crate::flower::supernode::SuperNodeConfig::max_run_contexts),
/// so long-finished runs' state is eventually evicted.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Context {
    pub run_id: u64,
    pub node_id: u64,
    /// Handler-owned persistent state (counters, personalization
    /// tensors, warm optimizer moments, ...).
    pub state: StateRecord,
}

impl Context {
    pub fn new(run_id: u64, node_id: u64) -> Context {
        Context {
            run_id,
            node_id,
            state: StateRecord::new(),
        }
    }
}

/// One typed message handler: consume an instruction [`Message`], use /
/// mutate the per-run [`Context`], return the reply. Implemented for
/// any `Fn(&Message, &mut Context) -> anyhow::Result<Message>` closure.
pub trait MessageHandler: Send + Sync {
    fn handle(&self, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message>;
}

impl<F> MessageHandler for F
where
    F: Fn(&Message, &mut Context) -> anyhow::Result<Message> + Send + Sync,
{
    fn handle(&self, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message> {
        self(msg, ctx)
    }
}

/// What a SuperNode executes: the message-level app surface. [`Router`]
/// is the registry implementation; [`crate::flower::mods::ModStack`]
/// wraps any `MessageApp` in middleware.
pub trait MessageApp: Send + Sync {
    fn handle(&self, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message>;

    /// Is a handler registered for this type? (Used for fail-fast
    /// checks; the authoritative answer is still `handle`'s error.)
    fn handles(&self, message_type: &MessageType) -> bool;
}

/// The handler registry: one handler per [`MessageType`], consulted by
/// the SuperNode for every received message. A message with no
/// registered handler yields a **typed error reply** (marker
/// [`UNHANDLED_MESSAGE_ERR`]) — never a panic, never a silent drop.
///
/// ```
/// use flarelink::flower::clientapp::{Context, Router};
/// use flarelink::flower::message::{ConfigRecord, Message, MessageType};
/// use flarelink::flower::records::{ConfigValue, RecordDict};
///
/// let app = Router::new().on_query(
///     |msg: &Message, ctx: &mut Context| -> anyhow::Result<Message> {
///         let n = ctx.state.bump("queries_seen", 1); // survives across rounds
///         let mut out = ConfigRecord::new();
///         out.insert("queries_seen", ConfigValue::I64(n));
///         Ok(msg.reply(RecordDict::from_configs(out)).with_examples(1))
///     },
/// );
/// let mut ctx = Context::new(1, 7);
/// let q = Message::query(7, ConfigRecord::new());
/// use flarelink::flower::clientapp::MessageApp;
/// let first = app.handle(&q, &mut ctx).unwrap();
/// let second = app.handle(&q, &mut ctx).unwrap();
/// assert_eq!(first.content.configs.get_i64("queries_seen"), Some(1));
/// assert_eq!(second.content.configs.get_i64("queries_seen"), Some(2));
/// assert!(!app.handles(&MessageType::Train));
/// ```
#[derive(Default)]
pub struct Router {
    handlers: Vec<(MessageType, Arc<dyn MessageHandler>)>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register (or replace) the handler for `message_type`.
    pub fn on(
        mut self,
        message_type: MessageType,
        handler: impl MessageHandler + 'static,
    ) -> Router {
        self.handlers.retain(|(t, _)| *t != message_type);
        self.handlers.push((message_type, Arc::new(handler)));
        self
    }

    pub fn on_train(self, handler: impl MessageHandler + 'static) -> Router {
        self.on(MessageType::Train, handler)
    }

    pub fn on_evaluate(self, handler: impl MessageHandler + 'static) -> Router {
        self.on(MessageType::Evaluate, handler)
    }

    pub fn on_query(self, handler: impl MessageHandler + 'static) -> Router {
        self.on(MessageType::Query, handler)
    }

    /// The blanket adapter: mount a classic fit/evaluate [`ClientApp`]
    /// as `Train`/`Evaluate` handlers. Dispatch, payloads, and error
    /// strings are byte-identical to the pre-registry SuperNode, so
    /// existing strategies/mods/tests run unchanged.
    pub fn from_client(app: Arc<dyn ClientApp>) -> Router {
        Router::new()
            .on(MessageType::Train, FitAdapter(app.clone()))
            .on(MessageType::Evaluate, EvalAdapter(app))
    }

    fn handler(&self, message_type: &MessageType) -> Option<&Arc<dyn MessageHandler>> {
        self.handlers
            .iter()
            .find(|(t, _)| t == message_type)
            .map(|(_, h)| h)
    }
}

impl MessageApp for Router {
    fn handle(&self, msg: &Message, ctx: &mut Context) -> anyhow::Result<Message> {
        match self.handler(&msg.message_type) {
            Some(h) => h.handle(msg, ctx),
            None => anyhow::bail!(
                "{UNHANDLED_MESSAGE_ERR} '{}' (node {} registered: [{}])",
                msg.message_type.name(),
                ctx.node_id,
                self.handlers
                    .iter()
                    .map(|(t, _)| t.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    fn handles(&self, message_type: &MessageType) -> bool {
        self.handler(message_type).is_some()
    }
}

// ---------------------------------------------------------------------------
// The classic fit/evaluate surface + its adapter
// ---------------------------------------------------------------------------

/// Result of a local `fit` (train) call.
#[derive(Clone, Debug)]
pub struct FitOutput {
    pub parameters: ArrayRecord,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

impl FitOutput {
    /// Package as the reply to instruction `ins` (what the Train
    /// adapter sends back: parameters + metrics + example count).
    ///
    /// Honors the server's negotiated uplink codec: when the fit config
    /// carries [`WIRE_CODEC_KEY`], the reply parameters are compressed
    /// with that codec before they touch the wire (delta encodes
    /// against the instruction's own parameters + model version). A
    /// codec name this node does not recognize yields a **typed
    /// refusal reply** (marker [`UNSUPPORTED_CODEC_ERR`], mirroring
    /// [`UNHANDLED_MESSAGE_ERR`]) — never a panic, never a silently
    /// wrong encoding.
    pub fn into_reply(self, ins: &Message) -> Message {
        let parameters = match ins.content.configs.get_str(WIRE_CODEC_KEY) {
            None => self.parameters,
            Some(name) => match WireCodec::from_name(name) {
                Some(codec) => self.parameters.compress(
                    codec,
                    Some((&ins.content.arrays, ins.metadata.model_version)),
                ),
                None => {
                    return ins.reply_err(format!(
                        "{UNSUPPORTED_CODEC_ERR}: node cannot encode '{name}'"
                    ));
                }
            },
        };
        ins.reply(RecordDict {
            arrays: parameters,
            metrics: self.metrics,
            configs: ConfigRecord::new(),
        })
        .with_examples(self.num_examples)
    }

    /// Recover from a (successful) Train reply — the inverse of
    /// [`FitOutput::into_reply`]; fails on error replies.
    pub fn from_reply(reply: Message) -> anyhow::Result<FitOutput> {
        anyhow::ensure!(reply.is_ok(), "{}", reply.error);
        Ok(FitOutput {
            parameters: reply.content.arrays,
            num_examples: reply.metadata.num_examples,
            metrics: reply.content.metrics,
        })
    }
}

/// Result of a local `evaluate` call.
#[derive(Clone, Debug)]
pub struct EvalOutput {
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

impl EvalOutput {
    /// Package as the reply to instruction `ins` (no parameters —
    /// evaluation returns loss + metrics only).
    pub fn into_reply(self, ins: &Message) -> Message {
        ins.reply(RecordDict {
            arrays: ArrayRecord::new(),
            metrics: self.metrics,
            configs: ConfigRecord::new(),
        })
        .with_examples(self.num_examples)
        .with_loss(self.loss)
    }

    /// Recover from a (successful) Evaluate reply.
    pub fn from_reply(reply: Message) -> anyhow::Result<EvalOutput> {
        anyhow::ensure!(reply.is_ok(), "{}", reply.error);
        Ok(EvalOutput {
            loss: reply.metadata.loss,
            num_examples: reply.metadata.num_examples,
            metrics: reply.content.metrics,
        })
    }
}

/// The NumPyClient-style interface (paper Listing 2: `fit`/`evaluate`).
/// Mounted onto the message surface by [`Router::from_client`].
pub trait ClientApp: Send + Sync {
    fn fit(&self, parameters: &ArrayRecord, config: &ConfigRecord) -> anyhow::Result<FitOutput>;
    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput>;
}

struct FitAdapter(Arc<dyn ClientApp>);

impl MessageHandler for FitAdapter {
    fn handle(&self, msg: &Message, _ctx: &mut Context) -> anyhow::Result<Message> {
        Ok(self
            .0
            .fit(&msg.content.arrays, &msg.content.configs)?
            .into_reply(msg))
    }
}

struct EvalAdapter(Arc<dyn ClientApp>);

impl MessageHandler for EvalAdapter {
    fn handle(&self, msg: &Message, _ctx: &mut Context) -> anyhow::Result<Message> {
        Ok(self
            .0
            .evaluate(&msg.content.arrays, &msg.content.configs)?
            .into_reply(msg))
    }
}

/// Deterministic toy client used across tests: `fit` adds `delta` to
/// every element of every tensor (per-tensor, preserving names, shapes,
/// and dtypes) and reports `n` examples; `evaluate` returns the mean of
/// all elements as "loss".
pub struct ArithmeticClient {
    pub delta: f32,
    pub n: u64,
}

impl ClientApp for ArithmeticClient {
    fn fit(&self, parameters: &ArrayRecord, _config: &ConfigRecord) -> anyhow::Result<FitOutput> {
        let delta = self.delta as f64;
        Ok(FitOutput {
            parameters: parameters.map_f64(|_, _, v| v + delta),
            num_examples: self.n,
            metrics: vec![("train_loss".to_string(), self.delta as f64)].into(),
        })
    }

    fn evaluate(
        &self,
        parameters: &ArrayRecord,
        _config: &ConfigRecord,
    ) -> anyhow::Result<EvalOutput> {
        let n = parameters.total_elems();
        let mut sum = 0.0f64;
        for t in parameters.tensors() {
            for i in 0..t.elems() {
                sum += t.get_f64(i);
            }
        }
        let mean = sum / n.max(1) as f64;
        Ok(EvalOutput {
            loss: mean,
            num_examples: self.n,
            metrics: vec![("accuracy".to_string(), 1.0 - mean.abs().min(1.0))].into(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::records::{ConfigValue, Tensor};

    #[test]
    fn arithmetic_client_behaviour() {
        let c = ArithmeticClient { delta: 0.5, n: 8 };
        let fit = c
            .fit(&ArrayRecord::from_flat(&[1.0, 2.0]), &ConfigRecord::new())
            .unwrap();
        assert_eq!(fit.parameters.to_flat(), vec![1.5, 2.5]);
        assert_eq!(fit.num_examples, 8);
        let ev = c
            .evaluate(&ArrayRecord::from_flat(&[1.0, 3.0]), &ConfigRecord::new())
            .unwrap();
        assert!((ev.loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn arithmetic_client_preserves_multi_tensor_structure() {
        let rec = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2], &[1.0, 2.0]),
            Tensor::from_i64("steps", vec![2], &[10, 20]),
        ])
        .unwrap();
        let c = ArithmeticClient { delta: 1.0, n: 1 };
        let out = c.fit(&rec, &ConfigRecord::new()).unwrap();
        assert!(out.parameters.dims_match(&rec));
        assert_eq!(out.parameters.get("w").unwrap().get_f64(0), 2.0);
        assert_eq!(out.parameters.get("steps").unwrap().get_f64(1), 21.0);
    }

    #[test]
    fn router_adapter_matches_direct_calls_bitexact() {
        // The blanket adapter path must be byte-identical to calling
        // fit/evaluate directly — the conformance anchor.
        let app: Arc<dyn ClientApp> = Arc::new(ArithmeticClient { delta: 1.5, n: 4 });
        let router = Router::from_client(app.clone());
        let params = ArrayRecord::from_flat(&[1.0, -2.0, f32::NAN]);
        let cfg = ConfigRecord::from_pairs(vec![("round".to_string(), ConfigValue::I64(1))]);

        let direct = app.fit(&params, &cfg).unwrap();
        let mut ctx = Context::new(1, 3);
        let ins = Message::train(3, params.clone(), cfg.clone()).for_round(1, 1);
        let via_msg = FitOutput::from_reply(router.handle(&ins, &mut ctx).unwrap()).unwrap();
        assert!(via_msg.parameters.bits_equal(&direct.parameters));
        assert_eq!(via_msg.num_examples, direct.num_examples);
        assert_eq!(via_msg.metrics, direct.metrics);

        let direct_ev = app.evaluate(&params, &cfg).unwrap();
        let ev_ins = Message::evaluate(3, params, cfg).for_round(1, 1);
        let via_ev = EvalOutput::from_reply(router.handle(&ev_ins, &mut ctx).unwrap()).unwrap();
        assert_eq!(via_ev.loss.to_bits(), direct_ev.loss.to_bits());
        assert_eq!(via_ev.num_examples, direct_ev.num_examples);
        assert_eq!(via_ev.metrics, direct_ev.metrics);
    }

    #[test]
    fn unregistered_type_is_a_typed_error() {
        let router = Router::from_client(Arc::new(ArithmeticClient { delta: 1.0, n: 1 }));
        let mut ctx = Context::new(1, 5);
        let q = Message::query(5, ConfigRecord::new());
        let err = router.handle(&q, &mut ctx).unwrap_err().to_string();
        assert!(is_unhandled(&err), "{err}");
        assert!(err.contains("query"), "{err}");
        assert!(err.contains("train"), "error lists registered types: {err}");
        assert!(!router.handles(&MessageType::Query));
        assert!(router.handles(&MessageType::Train));
    }

    #[test]
    fn custom_handler_registration_and_context_state() {
        let router = Router::new().on(
            MessageType::custom("echo_count"),
            |msg: &Message, ctx: &mut Context| -> anyhow::Result<Message> {
                let n = ctx.state.bump("calls", 1);
                let mut out = ConfigRecord::new();
                out.insert("calls", ConfigValue::I64(n));
                Ok(msg.reply(RecordDict::from_configs(out)))
            },
        );
        let mut ctx = Context::new(9, 2);
        let msg = Message::new(
            MessageType::custom("echo_count"),
            2,
            RecordDict::default(),
        );
        for want in 1..=3 {
            let reply = router.handle(&msg, &mut ctx).unwrap();
            assert_eq!(reply.content.configs.get_i64("calls"), Some(want));
        }
        // A second context (another run) is isolated.
        let mut other = Context::new(10, 2);
        let reply = router.handle(&msg, &mut other).unwrap();
        assert_eq!(reply.content.configs.get_i64("calls"), Some(1));
    }
}
