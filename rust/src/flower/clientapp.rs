//! Flower ClientApp: user code run by a SuperNode (paper Listing 2's
//! `NumPyClient` analogue). Implementations receive the global flat
//! parameter vector plus a config record and return updated parameters /
//! evaluation results.

use crate::flower::message::{ConfigRecord, MetricRecord};

/// Result of a local `fit` (train) call.
#[derive(Clone, Debug)]
pub struct FitOutput {
    pub parameters: Vec<f32>,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

/// Result of a local `evaluate` call.
#[derive(Clone, Debug)]
pub struct EvalOutput {
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

/// The NumPyClient-style interface (paper Listing 2: `fit`/`evaluate`).
pub trait ClientApp: Send + Sync {
    fn fit(&self, parameters: &[f32], config: &ConfigRecord) -> anyhow::Result<FitOutput>;
    fn evaluate(&self, parameters: &[f32], config: &ConfigRecord) -> anyhow::Result<EvalOutput>;
}

/// Deterministic toy client used across tests: `fit` adds `delta` to
/// every parameter and reports `n` examples; `evaluate` returns the mean
/// of the parameters as "loss".
pub struct ArithmeticClient {
    pub delta: f32,
    pub n: u64,
}

impl ClientApp for ArithmeticClient {
    fn fit(&self, parameters: &[f32], _config: &ConfigRecord) -> anyhow::Result<FitOutput> {
        Ok(FitOutput {
            parameters: parameters.iter().map(|p| p + self.delta).collect(),
            num_examples: self.n,
            metrics: vec![("train_loss".into(), self.delta as f64)],
        })
    }

    fn evaluate(&self, parameters: &[f32], _config: &ConfigRecord) -> anyhow::Result<EvalOutput> {
        let mean =
            parameters.iter().map(|p| *p as f64).sum::<f64>() / parameters.len().max(1) as f64;
        Ok(EvalOutput {
            loss: mean,
            num_examples: self.n,
            metrics: vec![("accuracy".into(), 1.0 - mean.abs().min(1.0))],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_client_behaviour() {
        let c = ArithmeticClient { delta: 0.5, n: 8 };
        let fit = c.fit(&[1.0, 2.0], &vec![]).unwrap();
        assert_eq!(fit.parameters, vec![1.5, 2.5]);
        assert_eq!(fit.num_examples, 8);
        let ev = c.evaluate(&[1.0, 3.0], &vec![]).unwrap();
        assert!((ev.loss - 2.0).abs() < 1e-9);
    }
}
