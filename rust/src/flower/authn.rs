//! Wire authentication for the v2 Flower frame protocol: every frame
//! between a SuperNode and the SuperLink is wrapped in an authentication
//! envelope — a per-node HMAC-SHA256 (hand-rolled, vendored-dep-free
//! like the CRC in `persist/wal.rs`) over the frame plus an
//! anti-replay counter. Keys are derived from the provisioning root
//! secret ([`crate::flare::provision::derive_node_key`]): each node
//! receives exactly its own key in its startup kit, so a client can
//! sign as itself but never as a peer, and the SuperLink (holding the
//! derivation secret) can verify any node.
//!
//! Envelope layout (fixed [`AUTH_HEADER`]-byte prefix, then the
//! untouched inner v2 frame):
//!
//! ```text
//! [magic 0xA7][dir u8][node_id u64 LE][counter u64 LE][mac 32B][inner frame]
//! ```
//!
//! The MAC covers `dir ‖ node_id ‖ counter ‖ inner`, so a frame can be
//! neither tampered with, re-attributed to another node, redirected
//! (client→server vs server→client), nor replayed under a reused
//! counter. Replay protection is an IPsec-style sliding window
//! ([`ReplayWindow`]): out-of-order delivery inside the window (mux
//! worker pools, dual rpc/push streams) is tolerated, duplicates and
//! ancient counters are dropped with a typed error.
//!
//! **Threat model.** This authenticates *frames*, not *content*: a
//! provisioned-but-malicious node still signs whatever lies it likes
//! (poisoned tensors, misreported `num_examples`) — that axis belongs
//! to [`crate::flower::committee`]. Rejection replies are necessarily
//! unsigned (the link may not even be able to attribute the frame), so
//! an attacker able to inject frames can forge *errors* — a denial of
//! service it could achieve by dropping frames anyway, never an
//! impersonation. The HMAC here models real mTLS/Ed25519 channel
//! authentication; see DESIGN.md §Substitutions.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::flare::provision::derive_node_key;
use crate::util::bytes::Bytes;
use crate::util::hash::{macs_equal, HmacSha256};

/// First byte of an authenticated frame (distinct from the v2 frame
/// magic `0xF2` and every v1 legacy tag).
pub const AUTH_MAGIC: u8 = 0xA7;
/// Fixed envelope prefix: magic + dir + node_id + counter + MAC.
pub const AUTH_HEADER: usize = 1 + 1 + 8 + 8 + 32;
/// Direction byte: SuperNode → SuperLink.
pub const DIR_TO_LINK: u8 = 0;
/// Direction byte: SuperLink → SuperNode.
pub const DIR_FROM_LINK: u8 = 1;

/// Marker carried by every wire-level authentication rejection. Clients
/// classify on it: an `Error` frame containing this is a FATAL typed
/// refusal — never a lease miss, never a torn frame, never a reason to
/// re-register and retry.
pub const AUTHN_ERR: &str = "authn rejected";

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AuthnError {
    /// The frame carries no authentication envelope at all.
    Missing,
    /// Too short to hold the fixed envelope prefix.
    Truncated,
    /// Envelope direction byte is wrong for this receiver.
    WrongDirection { got: u8 },
    /// Envelope names a different node than this verifier serves.
    WrongNode { got: u64, expected: u64 },
    /// MAC did not verify under the named node's key: forged, tampered,
    /// or signed with the wrong (e.g. a peer's) key.
    BadMac { node_id: u64 },
    /// Counter already seen (or aged out of the window): a replay.
    Replay { node_id: u64, counter: u64 },
}

impl std::fmt::Display for AuthnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AuthnError::Missing => write!(f, "frame lacks an authentication envelope"),
            AuthnError::Truncated => write!(f, "authentication envelope truncated"),
            AuthnError::WrongDirection { got } => {
                write!(f, "wrong envelope direction {got}")
            }
            AuthnError::WrongNode { got, expected } => {
                write!(f, "envelope for node {got}, expected node {expected}")
            }
            AuthnError::BadMac { node_id } => {
                write!(f, "bad frame MAC for node {node_id} (forged or tampered)")
            }
            AuthnError::Replay { node_id, counter } => {
                write!(f, "replayed counter {counter} for node {node_id}")
            }
        }
    }
}

impl std::error::Error for AuthnError {}

fn mac_over(key: &[u8; 32], dir: u8, node_id: u64, counter: u64, inner: &[u8]) -> [u8; 32] {
    let mut m = HmacSha256::new(key);
    m.update(&[dir]);
    m.update(&node_id.to_le_bytes());
    m.update(&counter.to_le_bytes());
    m.update(inner);
    m.finalize()
}

/// Wrap `inner` in an authentication envelope.
pub fn seal(key: &[u8; 32], dir: u8, node_id: u64, counter: u64, inner: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(AUTH_HEADER + inner.len());
    out.push(AUTH_MAGIC);
    out.push(dir);
    out.extend_from_slice(&node_id.to_le_bytes());
    out.extend_from_slice(&counter.to_le_bytes());
    out.extend_from_slice(&mac_over(key, dir, node_id, counter, inner));
    out.extend_from_slice(inner);
    out
}

struct Envelope {
    dir: u8,
    node_id: u64,
    counter: u64,
}

fn parse(frame: &[u8]) -> Result<Envelope, AuthnError> {
    if frame.first() != Some(&AUTH_MAGIC) {
        return Err(AuthnError::Missing);
    }
    if frame.len() < AUTH_HEADER {
        return Err(AuthnError::Truncated);
    }
    Ok(Envelope {
        dir: frame[1],
        node_id: u64::from_le_bytes(frame[2..10].try_into().unwrap()),
        counter: u64::from_le_bytes(frame[10..18].try_into().unwrap()),
    })
}

fn verify(key: &[u8; 32], env: &Envelope, frame: &[u8]) -> bool {
    let expected = mac_over(key, env.dir, env.node_id, env.counter, &frame[AUTH_HEADER..]);
    macs_equal(&frame[18..AUTH_HEADER], &expected)
}

/// Sliding anti-replay window (IPsec-style): accepts each counter at
/// most once, tolerates out-of-order delivery up to [`WINDOW_BITS`]
/// behind the highest counter seen, rejects anything older. Counter 0
/// is never valid (senders start at 1).
pub struct ReplayWindow {
    highest: u64,
    /// Bit `age` (= `highest - counter`) set ⇔ that counter was seen.
    seen: [u64; WINDOW_WORDS],
}

const WINDOW_WORDS: usize = 16;
/// Window span in counters: generous enough for the dual-stream client
/// (unary replies and task pushes share one direction counter but are
/// consumed at different times).
pub const WINDOW_BITS: u64 = (WINDOW_WORDS as u64) * 64;

impl Default for ReplayWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplayWindow {
    pub fn new() -> ReplayWindow {
        ReplayWindow {
            highest: 0,
            seen: [0; WINDOW_WORDS],
        }
    }

    fn test(&self, age: u64) -> bool {
        self.seen[(age / 64) as usize] & (1u64 << (age % 64)) != 0
    }

    fn set(&mut self, age: u64) {
        self.seen[(age / 64) as usize] |= 1u64 << (age % 64);
    }

    /// Age every recorded bit by `s` (the window just advanced by `s`).
    fn shift(&mut self, s: u64) {
        if s >= WINDOW_BITS {
            self.seen = [0; WINDOW_WORDS];
            return;
        }
        let words = (s / 64) as usize;
        let bits = (s % 64) as u32;
        for i in (0..WINDOW_WORDS).rev() {
            let src = i as isize - words as isize;
            let mut v = if src >= 0 {
                self.seen[src as usize] << bits
            } else {
                0
            };
            if bits > 0 && src >= 1 {
                v |= self.seen[(src - 1) as usize] >> (64 - bits);
            }
            self.seen[i] = v;
        }
    }

    /// Accept `counter` exactly once; false on replay / too-old / zero.
    pub fn accept(&mut self, counter: u64) -> bool {
        if counter == 0 {
            return false;
        }
        if counter > self.highest {
            self.shift(counter - self.highest);
            self.highest = counter;
            self.set(0);
            return true;
        }
        let age = self.highest - counter;
        if age >= WINDOW_BITS || self.test(age) {
            return false;
        }
        self.set(age);
        true
    }
}

/// Server-side verifier/signer: holds the key-derivation secret, so it
/// can authenticate ANY node's frames and sign replies back. One per
/// SuperLink (see `SuperLink::set_authenticator`).
pub struct FrameAuthenticator {
    project: String,
    secret: Vec<u8>,
    keys: Mutex<HashMap<u64, [u8; 32]>>,
    /// Per-node inbound replay windows (client → link direction).
    windows: Mutex<HashMap<u64, ReplayWindow>>,
    /// Per-node outbound counters (link → client direction) — shared by
    /// unary replies and task-stream pushes.
    send: Mutex<HashMap<u64, u64>>,
}

impl FrameAuthenticator {
    pub fn new(project: &str, secret: &[u8]) -> Arc<FrameAuthenticator> {
        Arc::new(FrameAuthenticator {
            project: project.to_string(),
            secret: secret.to_vec(),
            keys: Mutex::new(HashMap::new()),
            windows: Mutex::new(HashMap::new()),
            send: Mutex::new(HashMap::new()),
        })
    }

    /// The wire key for `node_id` (derived on first use, then cached).
    pub fn node_key(&self, node_id: u64) -> [u8; 32] {
        let mut keys = self.keys.lock().unwrap();
        *keys
            .entry(node_id)
            .or_insert_with(|| derive_node_key(&self.secret, &self.project, node_id))
    }

    /// Verify one client frame: envelope shape, direction, MAC, replay
    /// window — in that order (only authentic frames may advance the
    /// window). Returns the AUTHENTICATED node id and the offset of the
    /// inner frame. Failures bump `authn.rejected` / `replay.dropped`.
    pub fn open_request(&self, frame: &[u8]) -> Result<(u64, usize), AuthnError> {
        let env = match parse(frame) {
            Ok(env) => env,
            Err(e) => {
                crate::telemetry::bump("authn.rejected", 1);
                return Err(e);
            }
        };
        if env.dir != DIR_TO_LINK {
            crate::telemetry::bump("authn.rejected", 1);
            return Err(AuthnError::WrongDirection { got: env.dir });
        }
        if !verify(&self.node_key(env.node_id), &env, frame) {
            crate::telemetry::bump("authn.rejected", 1);
            return Err(AuthnError::BadMac {
                node_id: env.node_id,
            });
        }
        let accepted = self
            .windows
            .lock()
            .unwrap()
            .entry(env.node_id)
            .or_default()
            .accept(env.counter);
        if !accepted {
            crate::telemetry::bump("replay.dropped", 1);
            return Err(AuthnError::Replay {
                node_id: env.node_id,
                counter: env.counter,
            });
        }
        Ok((env.node_id, AUTH_HEADER))
    }

    /// Sign one link → client frame for `node_id`.
    pub fn seal_reply(&self, node_id: u64, inner: &[u8]) -> Vec<u8> {
        let counter = {
            let mut send = self.send.lock().unwrap();
            let c = send.entry(node_id).or_insert(0);
            *c += 1;
            *c
        };
        seal(&self.node_key(node_id), DIR_FROM_LINK, node_id, counter, inner)
    }
}

/// Client-side signer/verifier: holds exactly ONE node's key (from its
/// startup kit) — it can prove its own identity and verify link
/// replies, but cannot mint a peer's MAC.
pub struct NodeSigner {
    node_id: u64,
    key: [u8; 32],
    send: AtomicU64,
    window: Mutex<ReplayWindow>,
}

impl NodeSigner {
    pub fn new(node_id: u64, key: [u8; 32]) -> Arc<NodeSigner> {
        Arc::new(NodeSigner {
            node_id,
            key,
            send: AtomicU64::new(0),
            window: Mutex::new(ReplayWindow::new()),
        })
    }

    /// Convenience: derive the node's key the way the provisioner does
    /// (simulator-side; a real deployment ships only the derived key).
    pub fn for_project(project: &str, secret: &[u8], node_id: u64) -> Arc<NodeSigner> {
        NodeSigner::new(node_id, derive_node_key(secret, project, node_id))
    }

    pub fn node_id(&self) -> u64 {
        self.node_id
    }

    /// Sign one outbound client frame.
    pub fn seal(&self, inner: &[u8]) -> Vec<u8> {
        let counter = self.send.fetch_add(1, Ordering::Relaxed) + 1;
        seal(&self.key, DIR_TO_LINK, self.node_id, counter, inner)
    }

    /// Verify one link → client frame and unwrap the inner frame
    /// (zero-copy slice of the envelope buffer). Failures bump the same
    /// telemetry counters as the server side.
    pub fn open_reply(&self, frame: Bytes) -> Result<Bytes, AuthnError> {
        let env = match parse(frame.as_slice()) {
            Ok(env) => env,
            Err(e) => {
                crate::telemetry::bump("authn.rejected", 1);
                return Err(e);
            }
        };
        if env.dir != DIR_FROM_LINK {
            crate::telemetry::bump("authn.rejected", 1);
            return Err(AuthnError::WrongDirection { got: env.dir });
        }
        if env.node_id != self.node_id {
            crate::telemetry::bump("authn.rejected", 1);
            return Err(AuthnError::WrongNode {
                got: env.node_id,
                expected: self.node_id,
            });
        }
        if !verify(&self.key, &env, frame.as_slice()) {
            crate::telemetry::bump("authn.rejected", 1);
            return Err(AuthnError::BadMac {
                node_id: env.node_id,
            });
        }
        if !self.window.lock().unwrap().accept(env.counter) {
            crate::telemetry::bump("replay.dropped", 1);
            return Err(AuthnError::Replay {
                node_id: env.node_id,
                counter: env.counter,
            });
        }
        Ok(frame.slice(AUTH_HEADER, frame.len() - AUTH_HEADER))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: u8) -> [u8; 32] {
        [b; 32]
    }

    #[test]
    fn seal_open_roundtrip() {
        let auth = FrameAuthenticator::new("proj", b"secret");
        let signer = NodeSigner::for_project("proj", b"secret", 7);
        let sealed = signer.seal(b"hello");
        let (node, off) = auth.open_request(&sealed).unwrap();
        assert_eq!(node, 7);
        assert_eq!(&sealed[off..], b"hello");
        // And the reply direction.
        let reply = auth.seal_reply(7, b"world");
        let inner = signer.open_reply(Bytes::from_vec(reply)).unwrap();
        assert_eq!(inner.as_slice(), b"world");
    }

    #[test]
    fn tampered_payload_rejected() {
        let auth = FrameAuthenticator::new("proj", b"secret");
        let signer = NodeSigner::for_project("proj", b"secret", 1);
        let mut sealed = signer.seal(b"payload");
        let last = sealed.len() - 1;
        sealed[last] ^= 0xFF;
        assert!(matches!(
            auth.open_request(&sealed),
            Err(AuthnError::BadMac { node_id: 1 })
        ));
    }

    #[test]
    fn cross_node_attribution_rejected() {
        // Node 2 signs a frame but stamps node 1's id on the envelope:
        // the MAC (keyed per node AND covering the id) fails.
        let auth = FrameAuthenticator::new("proj", b"secret");
        let k2 = derive_node_key(b"secret", "proj", 2);
        let forged = seal(&k2, DIR_TO_LINK, 1, 1, b"imposter");
        assert!(matches!(
            auth.open_request(&forged),
            Err(AuthnError::BadMac { node_id: 1 })
        ));
    }

    #[test]
    fn replayed_frame_rejected_exactly_once_accepted() {
        let auth = FrameAuthenticator::new("proj", b"secret");
        let signer = NodeSigner::for_project("proj", b"secret", 3);
        let sealed = signer.seal(b"x");
        assert!(auth.open_request(&sealed).is_ok());
        let before = crate::telemetry::counter("replay.dropped")
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(matches!(
            auth.open_request(&sealed),
            Err(AuthnError::Replay { node_id: 3, .. })
        ));
        let after = crate::telemetry::counter("replay.dropped")
            .load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(after, before + 1);
    }

    #[test]
    fn wrong_direction_and_missing_envelope_rejected() {
        let auth = FrameAuthenticator::new("proj", b"secret");
        let signer = NodeSigner::for_project("proj", b"secret", 1);
        // A reply frame played back at the server.
        let reply = auth.seal_reply(1, b"r");
        assert!(matches!(
            auth.open_request(&reply),
            Err(AuthnError::WrongDirection { got: DIR_FROM_LINK })
        ));
        // A bare v2 frame at an authenticated server.
        assert!(matches!(
            auth.open_request(&[0xF2, 0, 0]),
            Err(AuthnError::Missing)
        ));
        // Truncated envelope.
        assert!(matches!(
            auth.open_request(&[AUTH_MAGIC, 0, 1]),
            Err(AuthnError::Truncated)
        ));
        // A request frame played back at the client.
        let req = signer.seal(b"q");
        assert!(matches!(
            signer.open_reply(Bytes::from_vec(req)),
            Err(AuthnError::WrongDirection { got: DIR_TO_LINK })
        ));
    }

    #[test]
    fn client_rejects_reply_for_other_node() {
        let auth = FrameAuthenticator::new("proj", b"secret");
        let signer = NodeSigner::for_project("proj", b"secret", 1);
        let reply_for_2 = auth.seal_reply(2, b"r");
        assert!(matches!(
            signer.open_reply(Bytes::from_vec(reply_for_2)),
            Err(AuthnError::WrongNode {
                got: 2,
                expected: 1
            })
        ));
    }

    #[test]
    fn replay_window_slides_and_tolerates_reordering() {
        let mut w = ReplayWindow::new();
        assert!(!w.accept(0), "counter 0 never valid");
        assert!(w.accept(5));
        assert!(w.accept(3), "out-of-order inside the window accepted");
        assert!(!w.accept(3), "second sight is a replay");
        assert!(!w.accept(5));
        assert!(w.accept(4));
        // Advance far: everything at or below the horizon is too old.
        assert!(w.accept(5 + WINDOW_BITS + 10));
        assert!(!w.accept(5), "aged out of the window");
        assert!(!w.accept(10), "aged out of the window");
        // Still inside the fresh window.
        assert!(w.accept(5 + WINDOW_BITS + 9));
    }

    #[test]
    fn replay_window_dense_sweep() {
        // Every counter 1..=3000 in order, each accepted exactly once.
        let mut w = ReplayWindow::new();
        for c in 1..=3000u64 {
            assert!(w.accept(c), "counter {c}");
            assert!(!w.accept(c), "counter {c} replay");
        }
    }

    #[test]
    fn window_shift_across_word_boundaries() {
        let mut w = ReplayWindow::new();
        for &c in &[1u64, 64, 65, 128, 130, 1000] {
            assert!(w.accept(c), "counter {c}");
        }
        for &c in &[1u64, 64, 65, 128, 130, 1000] {
            assert!(!w.accept(c), "counter {c} must replay");
        }
        // 1000 - 1023 = below horizon only once we pass WINDOW_BITS.
        assert!(w.accept(999));
        assert!(!w.accept(999));
    }

    #[test]
    fn macs_differ_per_direction_node_and_counter() {
        let k = key(9);
        let base = mac_over(&k, 0, 1, 1, b"p");
        assert_ne!(base, mac_over(&k, 1, 1, 1, b"p"));
        assert_ne!(base, mac_over(&k, 0, 2, 1, b"p"));
        assert_ne!(base, mac_over(&k, 0, 1, 2, b"p"));
        assert_ne!(base, mac_over(&k, 0, 1, 1, b"q"));
    }
}
