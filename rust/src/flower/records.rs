//! The record model — Flower's `RecordDict` Message API, offline:
//! named, shaped, dtyped [`Tensor`]s bundled into an [`ArrayRecord`],
//! plus metric and config records, bundled into a [`RecordDict`].
//!
//! This replaces the seed's single flat `Vec<f32>` parameter
//! representation everywhere: real models are multi-tensor and
//! multi-dtype, and a flat vector forces full copies on every hop of
//! the six-hop bridge path and makes per-layer strategies, quantized
//! payloads, and partial updates unrepresentable.
//!
//! Tensor payloads are stored as little-endian packed bytes in a shared
//! [`Bytes`] buffer. Decoding a received frame into an `ArrayRecord`
//! performs **zero payload copies**: each tensor borrows the frame's
//! allocation (see `flower::message` and the `record_codec` bench).
//! Element access decodes scalars on the fly — aggregation reads
//! through [`Tensor::get_f64`] and materializes fresh buffers only for
//! its outputs, which is the compute boundary, not the wire.
//!
//! Bit-exactness (the paper's Fig. 5 claim) is byte-exactness here:
//! [`ArrayRecord::bits_equal`] and the derived `PartialEq` compare raw
//! payload bytes, so NaN payloads and signed zeros are preserved
//! end-to-end.

use std::collections::HashMap;

use crate::util::bytes::{Bytes, WireError};

// ---------------------------------------------------------------------------
// Config / metric records (moved here from `message.rs`; re-exported
// there for compatibility)
// ---------------------------------------------------------------------------

/// Values carried in a task's config record (Flower's `ConfigRecord`).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    F64(f64),
    I64(i64),
    Str(String),
    Bool(bool),
}

/// Ordered, key-indexed config entries (Flower's `ConfigRecord`).
///
/// Iteration order is **deterministic** — entries keep their insertion
/// order, which is also the wire encoding order (so re-keying a record
/// never reorders frames). Lookups go through an O(1) key index;
/// [`ConfigRecord::insert`] replaces an existing key **in place**,
/// preserving its position.
///
/// Derefs to the underlying `[(String, ConfigValue)]` slice, so
/// `len()`, `iter()`, indexing, and `for (k, v) in &record` all behave
/// like the `Vec<(String, ConfigValue)>` this type replaced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigRecord {
    entries: Vec<(String, ConfigValue)>,
    /// key -> position of its FIRST occurrence (wire decode may carry
    /// duplicate keys from hostile peers; lookups see the first, and
    /// entries are preserved verbatim for byte-exact re-encoding).
    index: HashMap<String, usize>,
}

impl ConfigRecord {
    pub fn new() -> ConfigRecord {
        ConfigRecord::default()
    }

    /// Build from pairs, preserving order (first occurrence wins the
    /// index on duplicate keys).
    pub fn from_pairs(pairs: impl IntoIterator<Item = (String, ConfigValue)>) -> ConfigRecord {
        let mut rec = ConfigRecord::new();
        for (k, v) in pairs {
            if !rec.index.contains_key(&k) {
                rec.index.insert(k.clone(), rec.entries.len());
            }
            rec.entries.push((k, v));
        }
        rec
    }

    /// Set `key` to `value`: replaces an existing entry in place
    /// (keeping its position — deterministic iteration order), appends
    /// otherwise.
    pub fn insert(&mut self, key: impl Into<String>, value: ConfigValue) {
        let key = key.into();
        match self.index.get(&key) {
            Some(&i) => self.entries[i].1 = value,
            None => {
                self.index.insert(key.clone(), self.entries.len());
                self.entries.push((key, value));
            }
        }
    }

    /// Compat shim for the `Vec` API this type replaced. NOTE the
    /// deliberate semantic upgrade on duplicate keys: where `Vec::push`
    /// appended a shadowed second entry (lookups kept returning the
    /// first), this replaces the existing value in place — the LAST
    /// push wins, and no dead duplicate rides the wire.
    pub fn push(&mut self, pair: (String, ConfigValue)) {
        self.insert(pair.0, pair.1);
    }

    /// Indexed lookup (O(1), first occurrence on duplicate keys).
    pub fn get(&self, key: &str) -> Option<&ConfigValue> {
        self.index.get(key).map(|&i| &self.entries[i].1)
    }

    /// `key` as f64 (F64 direct; I64 cast).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(ConfigValue::F64(x)) => Some(*x),
            Some(ConfigValue::I64(x)) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(ConfigValue::I64(x)) => Some(*x),
            _ => None,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(ConfigValue::Str(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(ConfigValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

impl std::ops::Deref for ConfigRecord {
    type Target = [(String, ConfigValue)];
    fn deref(&self) -> &Self::Target {
        &self.entries
    }
}

impl From<Vec<(String, ConfigValue)>> for ConfigRecord {
    fn from(pairs: Vec<(String, ConfigValue)>) -> ConfigRecord {
        ConfigRecord::from_pairs(pairs)
    }
}

impl FromIterator<(String, ConfigValue)> for ConfigRecord {
    fn from_iter<I: IntoIterator<Item = (String, ConfigValue)>>(iter: I) -> ConfigRecord {
        ConfigRecord::from_pairs(iter)
    }
}

impl<'a> IntoIterator for &'a ConfigRecord {
    type Item = &'a (String, ConfigValue);
    type IntoIter = std::slice::Iter<'a, (String, ConfigValue)>;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter()
    }
}

/// Ordered, key-indexed (name, f64) metrics (Flower's `MetricRecord`).
/// Same shape and guarantees as [`ConfigRecord`]: deterministic
/// (insertion) iteration order — the wire order — with an O(1) key
/// index, dereferencing to the underlying `[(String, f64)]` slice.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricRecord {
    entries: Vec<(String, f64)>,
    index: HashMap<String, usize>,
}

impl MetricRecord {
    pub fn new() -> MetricRecord {
        MetricRecord::default()
    }

    pub fn from_pairs(pairs: impl IntoIterator<Item = (String, f64)>) -> MetricRecord {
        let mut rec = MetricRecord::new();
        for (k, v) in pairs {
            if !rec.index.contains_key(&k) {
                rec.index.insert(k.clone(), rec.entries.len());
            }
            rec.entries.push((k, v));
        }
        rec
    }

    /// Set `key` to `value` (replace in place, or append).
    pub fn insert(&mut self, key: impl Into<String>, value: f64) {
        let key = key.into();
        match self.index.get(&key) {
            Some(&i) => self.entries[i].1 = value,
            None => {
                self.index.insert(key.clone(), self.entries.len());
                self.entries.push((key, value));
            }
        }
    }

    /// Compat shim for the `Vec` API this type replaced (duplicate
    /// keys replace in place — last push wins, see
    /// [`ConfigRecord::push`]).
    pub fn push(&mut self, pair: (String, f64)) {
        self.insert(pair.0, pair.1);
    }

    /// Indexed lookup (O(1)).
    pub fn get(&self, key: &str) -> Option<f64> {
        self.index.get(key).map(|&i| self.entries[i].1)
    }
}

impl std::ops::Deref for MetricRecord {
    type Target = [(String, f64)];
    fn deref(&self) -> &Self::Target {
        &self.entries
    }
}

impl From<Vec<(String, f64)>> for MetricRecord {
    fn from(pairs: Vec<(String, f64)>) -> MetricRecord {
        MetricRecord::from_pairs(pairs)
    }
}

impl FromIterator<(String, f64)> for MetricRecord {
    fn from_iter<I: IntoIterator<Item = (String, f64)>>(iter: I) -> MetricRecord {
        MetricRecord::from_pairs(iter)
    }
}

impl<'a> IntoIterator for &'a MetricRecord {
    type Item = &'a (String, f64);
    type IntoIter = std::slice::Iter<'a, (String, f64)>;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter()
    }
}

#[deprecated(note = "use ConfigRecord::get_f64")]
pub fn config_get_f64(c: &ConfigRecord, key: &str) -> Option<f64> {
    c.get_f64(key)
}

#[deprecated(note = "use ConfigRecord::get_i64")]
pub fn config_get_i64(c: &ConfigRecord, key: &str) -> Option<i64> {
    c.get_i64(key)
}

#[deprecated(note = "use ConfigRecord::get_str")]
pub fn config_get_str<'a>(c: &'a ConfigRecord, key: &str) -> Option<&'a str> {
    c.get_str(key)
}

// ---------------------------------------------------------------------------
// DType
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I64,
    U8,
}

impl DType {
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    pub fn wire_tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I64 => 2,
            DType::U8 => 3,
        }
    }

    pub fn from_wire_tag(tag: u8) -> Result<DType, WireError> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I64,
            3 => DType::U8,
            t => return Err(WireError::BadTag(t)),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I64 => "i64",
            DType::U8 => "u8",
        }
    }
}

// ---------------------------------------------------------------------------
// Encoding — per-tensor wire compression
// ---------------------------------------------------------------------------

/// Typed error marker for codec refusals: a peer asked for (or sent) a
/// wire encoding this build does not understand, or a driver refused a
/// codec its strategy cannot honour. Mirrors
/// `clientapp::UNHANDLED_MESSAGE_ERR` — the refusal travels as a typed
/// per-node error result, never a panic or a silent drop.
pub const UNSUPPORTED_CODEC_ERR: &str = "unsupported codec";

/// Is `error` a codec refusal (see [`UNSUPPORTED_CODEC_ERR`])?
pub fn is_unsupported_codec(error: &str) -> bool {
    error.starts_with(UNSUPPORTED_CODEC_ERR)
}

/// Config key carrying the negotiated wire codec name on fit
/// instructions. The driver writes it from `ServerConfig::codec`; the
/// client compresses its reply accordingly. Absent key = identity
/// (dense) — v1 peers and old configs keep working unchanged.
pub const WIRE_CODEC_KEY: &str = "wire_codec";

/// Keep ratio denominator for top-k sparsification: the encoder keeps
/// the `ceil(n / TOPK_KEEP_DENOM)` largest-magnitude elements.
pub const TOPK_KEEP_DENOM: usize = 4;

/// How one tensor's payload bytes are encoded on the wire. `Dense` is
/// the classic packed little-endian layout; everything else is a
/// compressed form carried per tensor via a codec tag alongside the
/// dtype tag (wire v2). All compressed numeric forms are defined over
/// logical `F32` tensors only; [`Encoding::DeltaXor`] is a bitwise (and
/// therefore lossless) transform valid for any dtype.
///
/// Payload layouts (all little-endian):
/// * `F16` / `BF16` — 2 bytes per element (IEEE half / bfloat16 bits).
/// * `Int8` — 1 byte per element; `value = zero_point + scale * q`.
/// * `TopK { k }` — `k` u32 element indices (strictly ascending),
///   then `k` f32 values (exact bit patterns of the kept elements);
///   absent elements decode as 0.0.
/// * `TopKInt8` — `k` u32 indices then `k` u8 quantized values.
/// * `DeltaXor { base_version }` — same length as dense; each byte is
///   XORed with the base model's payload at `base_version`. Must be
///   resolved via [`ArrayRecord::resolve_delta`] before element access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Encoding {
    Dense,
    F16,
    BF16,
    Int8 { scale: f32, zero_point: f32 },
    TopK { k: u32 },
    TopKInt8 { k: u32, scale: f32, zero_point: f32 },
    DeltaXor { base_version: u64 },
}

impl Encoding {
    pub fn wire_tag(&self) -> u8 {
        match self {
            Encoding::Dense => 0,
            Encoding::F16 => 1,
            Encoding::BF16 => 2,
            Encoding::Int8 { .. } => 3,
            Encoding::TopK { .. } => 4,
            Encoding::TopKInt8 { .. } => 5,
            Encoding::DeltaXor { .. } => 6,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Encoding::Dense => "dense",
            Encoding::F16 => "fp16",
            Encoding::BF16 => "bf16",
            Encoding::Int8 { .. } => "int8",
            Encoding::TopK { .. } => "topk",
            Encoding::TopKInt8 { .. } => "int8_topk",
            Encoding::DeltaXor { .. } => "delta",
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Encoding::Dense)
    }

    /// Does decoding lose information? Quantized forms do; `Dense` and
    /// the bitwise `DeltaXor` do not. `TopK` counts as lossy here: it
    /// drops elements, which is only exact when the dropped elements
    /// are exactly zero (callers that know their updates are sparse get
    /// bit-exactness; a gate cannot know that).
    pub fn is_lossy(&self) -> bool {
        !matches!(self, Encoding::Dense | Encoding::DeltaXor { .. })
    }

    /// Exact encoded payload length in bytes for a tensor of `dtype`
    /// with `elems` elements (u64 math — wire-supplied `k` never
    /// truncates on narrow platforms).
    pub fn encoded_byte_len(&self, dtype: DType, elems: u64) -> u64 {
        match self {
            Encoding::Dense | Encoding::DeltaXor { .. } => {
                elems.saturating_mul(dtype.size_of() as u64)
            }
            Encoding::F16 | Encoding::BF16 => elems.saturating_mul(2),
            Encoding::Int8 { .. } => elems,
            Encoding::TopK { k } => (*k as u64).saturating_mul(8),
            Encoding::TopKInt8 { k, .. } => (*k as u64).saturating_mul(5),
        }
    }

    /// Compressed numeric encodings are defined over logical F32
    /// tensors only (DeltaXor is bitwise and dtype-agnostic).
    pub fn requires_f32(&self) -> bool {
        !matches!(self, Encoding::Dense | Encoding::DeltaXor { .. })
    }
}

/// The negotiated wire codec policy — what [`WIRE_CODEC_KEY`] carries
/// and what [`ArrayRecord::compress`] applies per tensor. `Identity`
/// leaves every tensor dense.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireCodec {
    #[default]
    Identity,
    F16,
    Bf16,
    Int8,
    TopK,
    Int8TopK,
    Delta,
}

impl WireCodec {
    pub fn name(self) -> &'static str {
        match self {
            WireCodec::Identity => "identity",
            WireCodec::F16 => "fp16",
            WireCodec::Bf16 => "bf16",
            WireCodec::Int8 => "int8",
            WireCodec::TopK => "topk",
            WireCodec::Int8TopK => "int8_topk",
            WireCodec::Delta => "delta",
        }
    }

    /// Parse a negotiation-key value. `None` = unknown codec (e.g. from
    /// a newer peer) — the caller must refuse with a typed
    /// [`UNSUPPORTED_CODEC_ERR`], never guess.
    pub fn from_name(s: &str) -> Option<WireCodec> {
        Some(match s {
            "identity" => WireCodec::Identity,
            "fp16" => WireCodec::F16,
            "bf16" => WireCodec::Bf16,
            "int8" => WireCodec::Int8,
            "topk" => WireCodec::TopK,
            "int8_topk" => WireCodec::Int8TopK,
            "delta" => WireCodec::Delta,
            _ => return None,
        })
    }

    /// Lossy codecs are refused by strategies whose arithmetic cannot
    /// survive quantization (`Strategy::supports_lossy_codec`, e.g.
    /// secure aggregation masks).
    pub fn is_lossy(self) -> bool {
        !matches!(self, WireCodec::Identity | WireCodec::Delta)
    }
}

// ---- f16 / bf16 bit conversions (no external deps; round-to-nearest-even)

/// f32 -> IEEE 754 binary16 bits, round-to-nearest-even. NaNs collapse
/// to the canonical quiet NaN (payloads don't survive — documented
/// lossy behaviour); overflow rounds to ±inf.
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs > 0x7f80_0000 {
        return sign | 0x7e00; // NaN -> canonical qNaN
    }
    if abs >= 0x477f_f000 {
        return sign | 0x7c00; // >= 65520 rounds to inf (f16 max = 65504)
    }
    let exp = (abs >> 23) as i32 - 127;
    if exp < -24 {
        return sign; // underflow to signed zero
    }
    if exp < -14 {
        // Subnormal f16: implicit bit restored, round-to-nearest-even.
        let man = (abs & 0x007f_ffff) | 0x0080_0000;
        let shift = (13 - 14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = man + (half - 1) + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    let exp16 = (exp + 15) as u32;
    let man = abs & 0x007f_ffff;
    let mut out = (exp16 << 10) | (man >> 13);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out += 1; // carry may bump the exponent — correct (rounds up)
    }
    sign | out as u16
}

/// IEEE 754 binary16 bits -> f32 (exact).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: normalize into f32's wider exponent range.
            let mut e: u32 = 113; // 127 - 15 + 1
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 bits, round-to-nearest-even. NaNs keep their sign
/// and are forced quiet.
pub(crate) fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fffu32 + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// bfloat16 bits -> f32 (exact).
pub(crate) fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[inline]
fn u16_at(s: &[u8], i: usize) -> u16 {
    u16::from_le_bytes([s[2 * i], s[2 * i + 1]])
}

#[inline]
fn u32_at(s: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([s[4 * i], s[4 * i + 1], s[4 * i + 2], s[4 * i + 3]])
}

#[inline]
fn dequant_int8(q: u8, scale: f32, zero_point: f32) -> f32 {
    zero_point + scale * q as f32
}

/// Affine quantization range for a slice of values: `(scale,
/// zero_point)` such that `value ≈ zero_point + scale * q`, `q ∈
/// [0, 255]`. Constant tensors get `scale = 0` and decode exactly.
/// Non-finite values are ignored for range selection (they clamp).
fn int8_range(vals: impl Iterator<Item = f32>) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for v in vals {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        let zp = if lo.is_finite() { lo } else { 0.0 };
        return (0.0, zp);
    }
    ((hi - lo) / 255.0, lo)
}

#[inline]
fn quant_int8(v: f32, scale: f32, zero_point: f32) -> u8 {
    if scale == 0.0 {
        return 0;
    }
    // NaN casts to 0, infinities saturate — Rust's float->int cast.
    ((v - zero_point) / scale).round().clamp(0.0, 255.0) as u8
}

// ---------------------------------------------------------------------------
// Tensor
// ---------------------------------------------------------------------------

/// A named, shaped, dtyped tensor whose payload is a little-endian
/// packed byte view into a shared buffer. Cloning is O(1).
///
/// The payload may be wire-compressed (see [`Encoding`]); `shape`
/// always describes the LOGICAL tensor, and element accessors
/// ([`Tensor::get_f64`], [`Tensor::fold_weighted`]) decode the encoding
/// on the fly — there is no eager dequantization buffer.
#[derive(Clone)]
pub struct Tensor {
    name: String,
    dtype: DType,
    shape: Vec<usize>,
    enc: Encoding,
    data: Bytes,
}

fn elems_of(shape: &[usize]) -> usize {
    shape.iter().product::<usize>()
}

impl Tensor {
    /// Wrap an existing byte view. Validates the payload length against
    /// dtype × shape.
    pub fn new(
        name: impl Into<String>,
        dtype: DType,
        shape: Vec<usize>,
        data: Bytes,
    ) -> anyhow::Result<Tensor> {
        Tensor::new_encoded(name, dtype, shape, Encoding::Dense, data)
    }

    /// Wrap an existing (possibly wire-compressed) byte view. Validates
    /// the payload length against the encoding's exact layout, the
    /// F32-only restriction of the numeric codecs, and — for top-k
    /// forms — that the index section is strictly ascending and in
    /// bounds (a hostile frame must not be able to aim a fold at an
    /// out-of-range accumulator slot or double-add an index).
    pub fn new_encoded(
        name: impl Into<String>,
        dtype: DType,
        shape: Vec<usize>,
        enc: Encoding,
        data: Bytes,
    ) -> anyhow::Result<Tensor> {
        let name = name.into();
        let elems = elems_of(&shape);
        anyhow::ensure!(
            !enc.requires_f32() || dtype == DType::F32,
            "tensor '{name}': encoding {} is only defined for f32, got {}",
            enc.name(),
            dtype.name()
        );
        let want = enc.encoded_byte_len(dtype, elems as u64);
        anyhow::ensure!(
            data.len() as u64 == want,
            "tensor '{name}': payload {} bytes, {} {} {:?} needs {want}",
            data.len(),
            enc.name(),
            dtype.name(),
            shape
        );
        if let Encoding::TopK { k } | Encoding::TopKInt8 { k, .. } = enc {
            let k = k as usize;
            anyhow::ensure!(
                k <= elems,
                "tensor '{name}': top-k keeps {k} of {elems} elements"
            );
            let s = data.as_slice();
            let mut prev: Option<u32> = None;
            for j in 0..k {
                let idx = u32_at(s, j);
                anyhow::ensure!(
                    (idx as usize) < elems,
                    "tensor '{name}': top-k index {idx} out of {elems}"
                );
                anyhow::ensure!(
                    prev.map_or(true, |p| idx > p),
                    "tensor '{name}': top-k indices not strictly ascending"
                );
                prev = Some(idx);
            }
        }
        Ok(Tensor {
            name,
            dtype,
            shape,
            enc,
            data,
        })
    }

    pub fn from_f32(name: impl Into<String>, shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::F32,
            shape,
            enc: Encoding::Dense,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_f64(name: impl Into<String>, shape: Vec<usize>, vals: &[f64]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::F64,
            shape,
            enc: Encoding::Dense,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_i64(name: impl Into<String>, shape: Vec<usize>, vals: &[i64]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::I64,
            shape,
            enc: Encoding::Dense,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_u8(name: impl Into<String>, shape: Vec<usize>, vals: &[u8]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        crate::telemetry::bump("records.pack_bytes", vals.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::U8,
            shape,
            enc: Encoding::Dense,
            data: Bytes::copy_from_slice(vals),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of elements.
    pub fn elems(&self) -> usize {
        elems_of(&self.shape)
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    /// The raw little-endian payload view (shared, zero-copy).
    pub fn data(&self) -> &Bytes {
        &self.data
    }

    /// The tensor's wire encoding (`Dense` for anything built by the
    /// plain constructors).
    pub fn encoding(&self) -> Encoding {
        self.enc
    }

    /// Binary-search the top-k index section for logical element `i`;
    /// returns the slot `j` such that `indices[j] == i`. Indices are
    /// validated strictly ascending at construction.
    fn topk_slot(&self, k: usize, i: usize) -> Option<usize> {
        let s = self.data.as_slice();
        let (mut lo, mut hi) = (0usize, k);
        while lo < hi {
            let mid = (lo + hi) / 2;
            let idx = u32_at(s, mid) as usize;
            match idx.cmp(&i) {
                std::cmp::Ordering::Equal => return Some(mid),
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
            }
        }
        None
    }

    /// Element `i` as f64, decoding the wire encoding on the fly
    /// (lossless for dense F32/F64; exact for I64/U8 within f64's
    /// 53-bit integer range; dequantized for compressed encodings;
    /// sparsified-away elements read 0.0). Panics for unresolved
    /// delta tensors — resolve via [`ArrayRecord::resolve_delta`]
    /// before element access (mirrors `get_bits_u64`'s dtype panic).
    #[inline]
    pub fn get_f64(&self, i: usize) -> f64 {
        let s = self.data.as_slice();
        match self.enc {
            Encoding::Dense => match self.dtype {
                DType::F32 => {
                    let o = i * 4;
                    f32::from_bits(u32::from_le_bytes([s[o], s[o + 1], s[o + 2], s[o + 3]])) as f64
                }
                DType::F64 => {
                    let o = i * 8;
                    f64::from_bits(u64::from_le_bytes([
                        s[o],
                        s[o + 1],
                        s[o + 2],
                        s[o + 3],
                        s[o + 4],
                        s[o + 5],
                        s[o + 6],
                        s[o + 7],
                    ]))
                }
                DType::I64 => self.get_bits_u64(i) as i64 as f64,
                DType::U8 => s[i] as f64,
            },
            Encoding::F16 => f16_bits_to_f32(u16_at(s, i)) as f64,
            Encoding::BF16 => bf16_bits_to_f32(u16_at(s, i)) as f64,
            Encoding::Int8 { scale, zero_point } => dequant_int8(s[i], scale, zero_point) as f64,
            Encoding::TopK { k } => match self.topk_slot(k as usize, i) {
                Some(j) => f32::from_bits(u32_at(s, k as usize + j)) as f64,
                None => 0.0,
            },
            Encoding::TopKInt8 {
                k,
                scale,
                zero_point,
            } => match self.topk_slot(k as usize, i) {
                Some(j) => dequant_int8(s[4 * k as usize + j], scale, zero_point) as f64,
                None => 0.0,
            },
            Encoding::DeltaXor { base_version } => panic!(
                "tensor '{}' is delta-encoded against model v{base_version} — \
                 resolve_delta before element access",
                self.name
            ),
        }
    }

    /// Raw 64-bit lane for I64 tensors (used by secure aggregation's
    /// exact wrapping arithmetic). Panics for other dtypes.
    #[inline]
    pub fn get_bits_u64(&self, i: usize) -> u64 {
        assert_eq!(self.dtype, DType::I64, "get_bits_u64 on {:?}", self.dtype);
        let s = self.data.as_slice();
        let o = i * 8;
        u64::from_le_bytes([
            s[o],
            s[o + 1],
            s[o + 2],
            s[o + 3],
            s[o + 4],
            s[o + 5],
            s[o + 6],
            s[o + 7],
        ])
    }

    /// Contiguous iterator over a DENSE F32 tensor's elements — the hot
    /// aggregation loops use this instead of per-index [`Tensor::get_f64`]
    /// so the reduction stays a vectorizable linear scan. Panics for
    /// other dtypes and for wire-compressed payloads (compressed
    /// tensors fold through [`Tensor::fold_weighted`] instead).
    pub fn f32_iter(&self) -> impl Iterator<Item = f32> + '_ {
        assert_eq!(self.dtype, DType::F32, "f32_iter on {:?}", self.dtype);
        assert!(
            self.enc.is_dense(),
            "f32_iter on {}-encoded tensor '{}'",
            self.enc.name(),
            self.name
        );
        self.data
            .as_slice()
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
    }

    /// Fold `w * element` into `acc` in ONE pass over the encoded
    /// payload — the quantized-aggregation hot path: fp16/bf16/int8
    /// segments dequantize here, at accumulate time, never into an
    /// intermediate dense buffer, and top-k forms touch only their `k`
    /// stored entries (absent elements contribute exactly 0).
    pub fn fold_weighted(&self, acc: &mut [f64], w: f64) {
        assert_eq!(acc.len(), self.elems(), "fold_weighted accumulator size");
        let s = self.data.as_slice();
        match self.enc {
            Encoding::Dense => match self.dtype {
                DType::F32 => {
                    for (o, c) in acc.iter_mut().zip(s.chunks_exact(4)) {
                        *o += w
                            * f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])) as f64;
                    }
                }
                _ => {
                    for (i, o) in acc.iter_mut().enumerate() {
                        *o += w * self.get_f64(i);
                    }
                }
            },
            Encoding::F16 => {
                for (o, c) in acc.iter_mut().zip(s.chunks_exact(2)) {
                    *o += w * f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])) as f64;
                }
            }
            Encoding::BF16 => {
                for (o, c) in acc.iter_mut().zip(s.chunks_exact(2)) {
                    *o += w * bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])) as f64;
                }
            }
            Encoding::Int8 { scale, zero_point } => {
                for (o, &q) in acc.iter_mut().zip(s.iter()) {
                    *o += w * dequant_int8(q, scale, zero_point) as f64;
                }
            }
            Encoding::TopK { k } => {
                let k = k as usize;
                for j in 0..k {
                    let idx = u32_at(s, j) as usize;
                    acc[idx] += w * f32::from_bits(u32_at(s, k + j)) as f64;
                }
            }
            Encoding::TopKInt8 {
                k,
                scale,
                zero_point,
            } => {
                let k = k as usize;
                for j in 0..k {
                    let idx = u32_at(s, j) as usize;
                    acc[idx] += w * dequant_int8(s[4 * k + j], scale, zero_point) as f64;
                }
            }
            Encoding::DeltaXor { base_version } => panic!(
                "tensor '{}' is delta-encoded against model v{base_version} — \
                 resolve_delta before aggregation",
                self.name
            ),
        }
    }

    /// Decode as f32, casting non-f32 dtypes and decompressing wire
    /// encodings (the canonical flat view). Top-k values keep their
    /// exact stored bit patterns.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let n = self.elems();
        let s = self.data.as_slice();
        match self.enc {
            Encoding::Dense => match self.dtype {
                DType::F32 => s
                    .chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                    .collect(),
                _ => (0..n).map(|i| self.get_f64(i) as f32).collect(),
            },
            Encoding::F16 => (0..n).map(|i| f16_bits_to_f32(u16_at(s, i))).collect(),
            Encoding::BF16 => (0..n).map(|i| bf16_bits_to_f32(u16_at(s, i))).collect(),
            Encoding::Int8 { scale, zero_point } => s
                .iter()
                .map(|&q| dequant_int8(q, scale, zero_point))
                .collect(),
            Encoding::TopK { k } => {
                let k = k as usize;
                let mut out = vec![0.0f32; n];
                for j in 0..k {
                    out[u32_at(s, j) as usize] = f32::from_bits(u32_at(s, k + j));
                }
                out
            }
            Encoding::TopKInt8 {
                k,
                scale,
                zero_point,
            } => {
                let k = k as usize;
                let mut out = vec![0.0f32; n];
                for j in 0..k {
                    out[u32_at(s, j) as usize] = dequant_int8(s[4 * k + j], scale, zero_point);
                }
                out
            }
            Encoding::DeltaXor { base_version } => panic!(
                "tensor '{}' is delta-encoded against model v{base_version} — \
                 resolve_delta before decoding",
                self.name
            ),
        }
    }

    /// Decompress into a dense tensor of the same name/dtype/shape
    /// (identity clone for dense input). Panics for unresolved delta
    /// tensors.
    pub fn to_dense(&self) -> Tensor {
        if self.enc.is_dense() {
            return self.clone();
        }
        Tensor::from_f32(self.name.clone(), self.shape.clone(), &self.to_f32_vec())
    }

    /// Compress a dense F32 tensor under `codec`. Non-F32, already
    /// compressed, and empty tensors pass through unchanged (so mixed
    /// records — e.g. secagg's masked I64 lanes — survive any policy).
    /// `base` supplies the (dense) base model tensor and its version
    /// for [`WireCodec::Delta`]; a missing or shape-mismatched base
    /// falls back to dense passthrough rather than corrupting bytes.
    pub fn compress(&self, codec: WireCodec, base: Option<(&Tensor, u64)>) -> Tensor {
        let n = self.elems();
        if !self.enc.is_dense() || codec == WireCodec::Identity || n == 0 {
            return self.clone();
        }
        if codec == WireCodec::Delta {
            return match base {
                Some((bt, version))
                    if bt.enc.is_dense()
                        && bt.dtype == self.dtype
                        && bt.shape == self.shape
                        && bt.data.len() == self.data.len() =>
                {
                    let xored: Vec<u8> = self
                        .data
                        .as_slice()
                        .iter()
                        .zip(bt.data.as_slice())
                        .map(|(a, b)| a ^ b)
                        .collect();
                    Tensor {
                        name: self.name.clone(),
                        dtype: self.dtype,
                        shape: self.shape.clone(),
                        enc: Encoding::DeltaXor {
                            base_version: version,
                        },
                        data: Bytes::from_vec(xored),
                    }
                }
                _ => self.clone(),
            };
        }
        if self.dtype != DType::F32 {
            return self.clone();
        }
        let (enc, data) = match codec {
            WireCodec::F16 => (
                Encoding::F16,
                self.f32_iter()
                    .flat_map(|v| f32_to_f16_bits(v).to_le_bytes())
                    .collect::<Vec<u8>>(),
            ),
            WireCodec::Bf16 => (
                Encoding::BF16,
                self.f32_iter()
                    .flat_map(|v| f32_to_bf16_bits(v).to_le_bytes())
                    .collect::<Vec<u8>>(),
            ),
            WireCodec::Int8 => {
                let (scale, zero_point) = int8_range(self.f32_iter());
                (
                    Encoding::Int8 { scale, zero_point },
                    self.f32_iter()
                        .map(|v| quant_int8(v, scale, zero_point))
                        .collect::<Vec<u8>>(),
                )
            }
            WireCodec::TopK | WireCodec::Int8TopK => {
                let k = (n + TOPK_KEEP_DENOM - 1) / TOPK_KEEP_DENOM;
                let mut order: Vec<(usize, f32)> = self.f32_iter().enumerate().collect();
                // Largest magnitude first; ties break on the lower
                // index — fully deterministic across platforms.
                order.sort_unstable_by(|a, b| {
                    b.1.abs()
                        .total_cmp(&a.1.abs())
                        .then_with(|| a.0.cmp(&b.0))
                });
                order.truncate(k);
                order.sort_unstable_by_key(|(i, _)| *i);
                let mut data = Vec::with_capacity(if codec == WireCodec::TopK {
                    k * 8
                } else {
                    k * 5
                });
                for (i, _) in &order {
                    data.extend_from_slice(&(*i as u32).to_le_bytes());
                }
                if codec == WireCodec::TopK {
                    for (_, v) in &order {
                        data.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                    (Encoding::TopK { k: k as u32 }, data)
                } else {
                    let (scale, zero_point) = int8_range(order.iter().map(|(_, v)| *v));
                    for (_, v) in &order {
                        data.push(quant_int8(*v, scale, zero_point));
                    }
                    (
                        Encoding::TopKInt8 {
                            k: k as u32,
                            scale,
                            zero_point,
                        },
                        data,
                    )
                }
            }
            WireCodec::Identity | WireCodec::Delta => unreachable!("handled above"),
        };
        crate::telemetry::bump("codec.compress_bytes_in", self.data.len() as i64);
        crate::telemetry::bump("codec.compress_bytes_out", data.len() as i64);
        Tensor {
            name: self.name.clone(),
            dtype: self.dtype,
            shape: self.shape.clone(),
            enc,
            data: Bytes::from_vec(data),
        }
    }

    /// Resolve a [`Encoding::DeltaXor`] tensor against its dense base:
    /// XOR is its own inverse, so this reconstructs the original bytes
    /// exactly. Errors (typed, never panics) on version or shape
    /// mismatch. Non-delta tensors pass through unchanged.
    pub fn resolve_delta(&self, base: &Tensor, expect_version: u64) -> anyhow::Result<Tensor> {
        let Encoding::DeltaXor { base_version } = self.enc else {
            return Ok(self.clone());
        };
        anyhow::ensure!(
            base_version == expect_version,
            "{UNSUPPORTED_CODEC_ERR}: tensor '{}' delta-encoded against model \
             v{base_version}, server base is v{expect_version}",
            self.name
        );
        anyhow::ensure!(
            base.enc.is_dense()
                && base.dtype == self.dtype
                && base.shape == self.shape
                && base.data.len() == self.data.len(),
            "{UNSUPPORTED_CODEC_ERR}: tensor '{}' delta base mismatch",
            self.name
        );
        let bytes: Vec<u8> = self
            .data
            .as_slice()
            .iter()
            .zip(base.data.as_slice())
            .map(|(a, b)| a ^ b)
            .collect();
        Ok(Tensor {
            name: self.name.clone(),
            dtype: self.dtype,
            shape: self.shape.clone(),
            enc: Encoding::Dense,
            data: Bytes::from_vec(bytes),
        })
    }

    /// Build a tensor of `dtype` from f64 values, casting per dtype
    /// (floats cast; I64 rounds; U8 rounds and saturates).
    pub fn from_f64_values(
        name: impl Into<String>,
        dtype: DType,
        shape: Vec<usize>,
        vals: impl Iterator<Item = f64>,
    ) -> Tensor {
        let name = name.into();
        match dtype {
            DType::F32 => {
                let v: Vec<f32> = vals.map(|x| x as f32).collect();
                Tensor::from_f32(name, shape, &v)
            }
            DType::F64 => {
                let v: Vec<f64> = vals.collect();
                Tensor::from_f64(name, shape, &v)
            }
            DType::I64 => {
                let v: Vec<i64> = vals.map(|x| x.round() as i64).collect();
                Tensor::from_i64(name, shape, &v)
            }
            DType::U8 => {
                let v: Vec<u8> = vals.map(|x| x.round().clamp(0.0, 255.0) as u8).collect();
                Tensor::from_u8(name, shape, &v)
            }
        }
    }

    /// Same name, dtype, and shape (payload not compared).
    pub fn dims_match(&self, other: &Tensor) -> bool {
        self.name == other.name && self.dtype == other.dtype && self.shape == other.shape
    }

    /// Byte-exact equality (name, dtype, shape, encoding, payload bits).
    pub fn bits_equal(&self, other: &Tensor) -> bool {
        self.dims_match(other) && self.enc == other.enc && self.data == other.data
    }
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.bits_equal(other)
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tensor({} {} {:?} {}, {} bytes)",
            self.name,
            self.dtype.name(),
            self.shape,
            self.enc.name(),
            self.data.len()
        )
    }
}

// ---------------------------------------------------------------------------
// ArrayRecord
// ---------------------------------------------------------------------------

/// Name used by the flat-compat shim for the single tensor wrapping a
/// legacy `Vec<f32>` parameter vector.
pub const FLAT_TENSOR: &str = "parameters";

/// Ordered collection of uniquely-named tensors — Flower's
/// `ArrayRecord`. Order is part of the canonical form: aggregation,
/// masking, and the flat view all iterate in record order, which is why
/// native and bridged runs stay bit-identical.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrayRecord {
    tensors: Vec<Tensor>,
}

impl ArrayRecord {
    pub fn new() -> ArrayRecord {
        ArrayRecord::default()
    }

    pub fn from_tensors(tensors: Vec<Tensor>) -> anyhow::Result<ArrayRecord> {
        // O(n) duplicate detection — this sits on the frame-decode path,
        // where a hostile frame can claim thousands of tensors.
        {
            let mut seen = std::collections::HashSet::with_capacity(tensors.len());
            for t in &tensors {
                anyhow::ensure!(seen.insert(t.name()), "duplicate tensor name '{}'", t.name());
            }
        }
        Ok(ArrayRecord { tensors })
    }

    pub fn push(&mut self, tensor: Tensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.get(tensor.name()).is_none(),
            "duplicate tensor name '{}'",
            tensor.name()
        );
        self.tensors.push(tensor);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name() == name)
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total element count across tensors.
    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }

    /// Total payload bytes across tensors.
    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_len()).sum()
    }

    /// Same tensor names/dtypes/shapes in the same order.
    pub fn dims_match(&self, other: &ArrayRecord) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(other.tensors.iter())
                .all(|(a, b)| a.dims_match(b))
    }

    /// Byte-exact equality across all tensors (NaN-safe — stronger than
    /// float `==`).
    pub fn bits_equal(&self, other: &ArrayRecord) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(other.tensors.iter())
                .all(|(a, b)| a.bits_equal(b))
    }

    /// Are all tensors dense (no wire compression)?
    pub fn is_all_dense(&self) -> bool {
        self.tensors.iter().all(|t| t.encoding().is_dense())
    }

    /// Does any tensor carry an unresolved delta encoding?
    pub fn has_delta(&self) -> bool {
        self.tensors
            .iter()
            .any(|t| matches!(t.encoding(), Encoding::DeltaXor { .. }))
    }

    /// Compress every eligible tensor under `codec` (see
    /// [`Tensor::compress`]); `base` supplies the dense base record +
    /// model version for [`WireCodec::Delta`], matched per tensor by
    /// name. Identity policy returns an O(1) clone.
    pub fn compress(&self, codec: WireCodec, base: Option<(&ArrayRecord, u64)>) -> ArrayRecord {
        if codec == WireCodec::Identity {
            return self.clone();
        }
        let tensors = self
            .tensors
            .iter()
            .map(|t| {
                let b = base.and_then(|(rec, ver)| rec.get(t.name()).map(|bt| (bt, ver)));
                t.compress(codec, b)
            })
            .collect();
        ArrayRecord { tensors }
    }

    /// Decompress every tensor to dense (identity for dense records).
    /// Panics on unresolved delta tensors — resolve first.
    pub fn to_dense(&self) -> ArrayRecord {
        ArrayRecord {
            tensors: self.tensors.iter().map(|t| t.to_dense()).collect(),
        }
    }

    /// Resolve any [`Encoding::DeltaXor`] tensors against `base` (the
    /// dense model the peer encoded against), verifying each tensor's
    /// claimed base version equals `expect_version`. Records with no
    /// delta tensors pass through as O(1)-per-tensor clones. Typed
    /// errors ([`UNSUPPORTED_CODEC_ERR`]) on version/shape/name
    /// mismatch — never a panic, never silently wrong bytes.
    pub fn resolve_delta(
        &self,
        base: &ArrayRecord,
        expect_version: u64,
    ) -> anyhow::Result<ArrayRecord> {
        if !self.has_delta() {
            return Ok(self.clone());
        }
        let mut tensors = Vec::with_capacity(self.tensors.len());
        for t in &self.tensors {
            if matches!(t.encoding(), Encoding::DeltaXor { .. }) {
                let bt = base.get(t.name()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "{UNSUPPORTED_CODEC_ERR}: delta tensor '{}' has no base tensor",
                        t.name()
                    )
                })?;
                tensors.push(t.resolve_delta(bt, expect_version)?);
            } else {
                tensors.push(t.clone());
            }
        }
        Ok(ArrayRecord { tensors })
    }

    // ---------------- flat-compat shim ----------------

    /// Wrap a legacy flat f32 vector as a single-tensor record (the
    /// mechanical migration path for examples/benches).
    pub fn from_flat(vals: &[f32]) -> ArrayRecord {
        ArrayRecord {
            tensors: vec![Tensor::from_f32(FLAT_TENSOR, vec![vals.len()], vals)],
        }
    }

    /// Canonical flattened f32 view: tensors concatenated in record
    /// order, non-f32 dtypes cast. Exact for all-F32 records.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_elems());
        for t in &self.tensors {
            out.extend(t.to_f32_vec());
        }
        out
    }

    /// Rebuild a record with THIS record's structure (names, shapes)
    /// from a flat f32 vector — the exact inverse of [`to_flat`], used
    /// by the train stack to round-trip layer-named tensors through the
    /// flat AOT artifacts.
    ///
    /// Only valid for all-F32 records: a flat f32 intermediate cannot
    /// represent i64/f64 payloads exactly, so rather than silently
    /// corrupting them this errors (the bit-exactness contract).
    ///
    /// [`to_flat`]: ArrayRecord::to_flat
    pub fn from_flat_like(&self, flat: &[f32]) -> anyhow::Result<ArrayRecord> {
        anyhow::ensure!(
            flat.len() == self.total_elems(),
            "flat vector has {} elems, record structure needs {}",
            flat.len(),
            self.total_elems()
        );
        let mut tensors = Vec::with_capacity(self.tensors.len());
        let mut off = 0;
        for t in &self.tensors {
            anyhow::ensure!(
                t.dtype() == DType::F32,
                "from_flat_like: tensor '{}' is {} — a flat f32 view cannot \
                 rebuild non-f32 payloads losslessly",
                t.name(),
                t.dtype().name()
            );
            let n = t.elems();
            tensors.push(Tensor::from_f32(t.name(), t.shape().to_vec(), &flat[off..off + n]));
            off += n;
        }
        Ok(ArrayRecord { tensors })
    }

    /// Element-wise transform preserving structure: `f(tensor_name,
    /// element_index, value)` over every tensor in record order, output
    /// cast back to each tensor's dtype.
    pub fn map_f64(&self, f: impl Fn(&str, usize, f64) -> f64) -> ArrayRecord {
        let tensors = self
            .tensors
            .iter()
            .map(|t| {
                Tensor::from_f64_values(
                    t.name(),
                    t.dtype(),
                    t.shape().to_vec(),
                    (0..t.elems()).map(|i| f(t.name(), i, t.get_f64(i))),
                )
            })
            .collect();
        ArrayRecord { tensors }
    }
}

/// Flat-compat helpers (the migration shim named by the redesign):
/// `compat::from_flat` / `compat::to_flat` are free-function aliases of
/// the [`ArrayRecord`] inherent methods.
pub mod compat {
    use super::ArrayRecord;

    pub fn from_flat(vals: &[f32]) -> ArrayRecord {
        ArrayRecord::from_flat(vals)
    }

    pub fn to_flat(rec: &ArrayRecord) -> Vec<f32> {
        rec.to_flat()
    }
}

// ---------------------------------------------------------------------------
// RecordDict
// ---------------------------------------------------------------------------

/// The full record bundle a message carries: arrays + metrics + configs
/// (Flower's `RecordDict`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecordDict {
    pub arrays: ArrayRecord,
    pub metrics: MetricRecord,
    pub configs: ConfigRecord,
}

impl RecordDict {
    pub fn from_arrays(arrays: ArrayRecord) -> RecordDict {
        RecordDict {
            arrays,
            metrics: MetricRecord::new(),
            configs: ConfigRecord::new(),
        }
    }

    pub fn from_configs(configs: ConfigRecord) -> RecordDict {
        RecordDict {
            arrays: ArrayRecord::new(),
            metrics: MetricRecord::new(),
            configs,
        }
    }
}

// ---------------------------------------------------------------------------
// StateRecord
// ---------------------------------------------------------------------------

/// Per-node mutable state that survives across rounds (Flower's
/// `Context.state`). A SuperNode keeps one per run and hands it to every
/// message handler — this is what makes stateful clients (counters,
/// personalization layers, warm optimizer state) possible without any
/// wire traffic: the state never leaves the node.
///
/// Scalar entries live in a [`ConfigRecord`]; tensor entries (e.g. a
/// warm optimizer moment) are name-keyed with replace-on-set semantics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StateRecord {
    configs: ConfigRecord,
    tensors: Vec<Tensor>,
}

impl StateRecord {
    pub fn new() -> StateRecord {
        StateRecord::default()
    }

    /// Set a scalar entry (replace or append, like
    /// [`ConfigRecord::insert`]).
    pub fn set(&mut self, key: impl Into<String>, value: ConfigValue) {
        self.configs.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&ConfigValue> {
        self.configs.get(key)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.configs.get_f64(key)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.configs.get_i64(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.configs.get_str(key)
    }

    /// Increment the I64 counter at `key` by `by` (missing counts as 0)
    /// and return the new value — the one-liner for "how many times has
    /// this node seen X".
    pub fn bump(&mut self, key: impl Into<String>, by: i64) -> i64 {
        let key = key.into();
        let next = self.configs.get_i64(&key).unwrap_or(0) + by;
        self.configs.insert(key, ConfigValue::I64(next));
        next
    }

    /// Store a tensor under its name (replacing any previous tensor of
    /// that name — state is a map, not a log).
    pub fn set_tensor(&mut self, tensor: Tensor) {
        match self.tensors.iter_mut().find(|t| t.name() == tensor.name()) {
            Some(slot) => *slot = tensor,
            None => self.tensors.push(tensor),
        }
    }

    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name() == name)
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty() && self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_record() -> ArrayRecord {
        ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2, 2], &[1.0, -2.0, 3.5, 0.25]),
            Tensor::from_f64("bias", vec![3], &[1e-12, -4.0, 2.5]),
            Tensor::from_i64("steps", vec![2], &[-7, 1 << 40]),
            Tensor::from_u8("mask", vec![4], &[0, 1, 254, 255]),
        ])
        .unwrap()
    }

    #[test]
    fn dtype_sizes_and_tags_roundtrip() {
        for d in [DType::F32, DType::F64, DType::I64, DType::U8] {
            assert_eq!(DType::from_wire_tag(d.wire_tag()).unwrap(), d);
            assert!(d.size_of() > 0);
        }
        assert!(DType::from_wire_tag(9).is_err());
    }

    #[test]
    fn tensor_element_access() {
        let r = mixed_record();
        assert_eq!(r.get("w").unwrap().get_f64(2), 3.5);
        assert_eq!(r.get("bias").unwrap().get_f64(1), -4.0);
        assert_eq!(r.get("steps").unwrap().get_f64(0), -7.0);
        assert_eq!(r.get("steps").unwrap().get_f64(1), (1u64 << 40) as f64);
        assert_eq!(r.get("mask").unwrap().get_f64(3), 255.0);
        assert_eq!(r.total_elems(), 4 + 3 + 2 + 4);
        assert_eq!(r.total_bytes(), 16 + 24 + 16 + 4);
    }

    #[test]
    fn tensor_new_validates_length() {
        let data = Bytes::from_vec(vec![0u8; 12]);
        assert!(Tensor::new("x", DType::F32, vec![3], data.clone()).is_ok());
        assert!(Tensor::new("x", DType::F32, vec![4], data.clone()).is_err());
        assert!(Tensor::new("x", DType::F64, vec![3], data).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut r = ArrayRecord::from_flat(&[1.0]);
        assert!(r.push(Tensor::from_f32(FLAT_TENSOR, vec![1], &[2.0])).is_err());
        assert!(r.push(Tensor::from_f32("other", vec![1], &[2.0])).is_ok());
    }

    #[test]
    fn flat_roundtrip_exact_for_f32() {
        let vals = [0.0f32, -0.0, f32::NAN, 1e-40, f32::MAX];
        let rec = ArrayRecord::from_flat(&vals);
        let back = rec.to_flat();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Structure-preserving rebuild.
        let rebuilt = rec.from_flat_like(&back).unwrap();
        assert!(rebuilt.bits_equal(&rec));
    }

    #[test]
    fn from_flat_like_validates_length_and_dtype() {
        let rec = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2, 2], &[1.0; 4]),
            Tensor::from_f32("b", vec![3], &[2.0; 3]),
        ])
        .unwrap();
        assert!(rec.from_flat_like(&[0.0; 3]).is_err(), "length mismatch");
        let ok = rec.from_flat_like(&[9.0; 7]).unwrap();
        assert!(ok.dims_match(&rec));
        assert_eq!(ok.get("b").unwrap().get_f64(0), 9.0);
        // Non-f32 structures refuse the lossy flat round-trip.
        assert!(mixed_record()
            .from_flat_like(&vec![1.0; mixed_record().total_elems()])
            .is_err());
    }

    #[test]
    fn map_preserves_structure_and_dtypes() {
        let rec = mixed_record();
        let doubled = rec.map_f64(|_, _, v| v * 2.0);
        assert!(doubled.dims_match(&rec));
        assert_eq!(doubled.get("w").unwrap().get_f64(0), 2.0);
        assert_eq!(doubled.get("steps").unwrap().get_f64(0), -14.0);
        // U8 saturates.
        assert_eq!(doubled.get("mask").unwrap().get_f64(3), 255.0);
    }

    #[test]
    fn bits_equal_nan_safe() {
        let a = ArrayRecord::from_flat(&[f32::NAN, -0.0]);
        let b = ArrayRecord::from_flat(&[f32::NAN, -0.0]);
        let c = ArrayRecord::from_flat(&[f32::NAN, 0.0]);
        assert!(a.bits_equal(&b));
        assert_eq!(a, b);
        assert!(!a.bits_equal(&c), "-0.0 and 0.0 differ bitwise");
    }

    #[test]
    fn dims_match_ignores_payload() {
        let a = ArrayRecord::from_flat(&[1.0, 2.0]);
        let b = ArrayRecord::from_flat(&[3.0, 4.0]);
        assert!(a.dims_match(&b));
        assert!(!a.bits_equal(&b));
        let c = ArrayRecord::from_flat(&[1.0]);
        assert!(!a.dims_match(&c));
    }

    #[test]
    fn compat_shim_is_mechanical() {
        let flat = vec![1.0f32, 2.0, 3.0];
        let rec = compat::from_flat(&flat);
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.tensors()[0].name(), FLAT_TENSOR);
        assert_eq!(compat::to_flat(&rec), flat);
    }

    #[test]
    fn config_record_indexed_get_and_in_place_insert() {
        let mut c = ConfigRecord::from_pairs(vec![
            ("lr".to_string(), ConfigValue::F64(0.1)),
            ("mode".to_string(), ConfigValue::Str("iid".into())),
            ("epochs".to_string(), ConfigValue::I64(2)),
        ]);
        assert_eq!(c.get_f64("lr"), Some(0.1));
        assert_eq!(c.get_f64("epochs"), Some(2.0), "I64 casts for get_f64");
        assert_eq!(c.get_i64("epochs"), Some(2));
        assert_eq!(c.get_str("mode"), Some("iid"));
        assert_eq!(c.get("missing"), None);
        // Replace keeps the key's position — iteration order is
        // deterministic under re-keying.
        c.insert("mode", ConfigValue::Str("skew".into()));
        let keys: Vec<&str> = c.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["lr", "mode", "epochs"]);
        assert_eq!(c.get_str("mode"), Some("skew"));
        assert_eq!(c.len(), 3);
        // Append lands at the end.
        c.push(("new".to_string(), ConfigValue::Bool(true)));
        assert_eq!(c.get_bool("new"), Some(true));
        assert_eq!(c.last().unwrap().0, "new");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_config_shims_still_work() {
        let c = ConfigRecord::from_pairs(vec![
            ("lr".to_string(), ConfigValue::F64(0.5)),
            ("mode".to_string(), ConfigValue::Str("iid".into())),
        ]);
        assert_eq!(config_get_f64(&c, "lr"), Some(0.5));
        assert_eq!(config_get_i64(&c, "lr"), None);
        assert_eq!(config_get_str(&c, "mode"), Some("iid"));
    }

    #[test]
    fn metric_record_indexed_and_ordered() {
        let mut m = MetricRecord::from_pairs(vec![
            ("loss".to_string(), 0.5),
            ("accuracy".to_string(), 0.9),
        ]);
        assert_eq!(m.get("accuracy"), Some(0.9));
        m.insert("loss", 0.25);
        assert_eq!(m.get("loss"), Some(0.25));
        let keys: Vec<&str> = m.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["loss", "accuracy"], "replace keeps position");
        // Slice view works like the old Vec.
        assert_eq!(m[0].1, 0.25);
        let collected: MetricRecord = vec![("a".to_string(), 1.0)].into_iter().collect();
        assert_eq!(collected.get("a"), Some(1.0));
    }

    // ------------------------------------------------------------------
    // Wire-compression codecs
    // ------------------------------------------------------------------

    fn fold_of(t: &Tensor) -> Vec<f64> {
        let mut acc = vec![0.0f64; t.elems()];
        t.fold_weighted(&mut acc, 1.0);
        acc
    }

    #[test]
    fn f16_conversions_roundtrip_representable_values() {
        for v in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1035156e-5, // min normal
            5.9604645e-8, // min subnormal
            f32::INFINITY,
            f32::NEG_INFINITY,
        ] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "f16 roundtrip of {v}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Overflow rounds to inf; tiny values flush to signed zero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e-9)).to_bits(), (-0.0f32).to_bits());
        // Round-to-nearest-even on a halfway mantissa.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 1.0 / 2048.0)), 1.0);
    }

    #[test]
    fn bf16_conversions_roundtrip_representable_values() {
        for v in [0.0f32, -0.0, 1.0, -2.5, 3.0e38, 1e-38, f32::INFINITY] {
            let bits = f32_to_bf16_bits(v);
            let back = bf16_bits_to_f32(bits);
            // bf16-representable values survive exactly.
            assert_eq!(f32_to_bf16_bits(back), bits, "bf16 restable {v}");
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        // Relative error bounded by the 8-bit mantissa.
        let v = 1.2345678f32;
        let back = bf16_bits_to_f32(f32_to_bf16_bits(v));
        assert!((back - v).abs() / v.abs() < 1.0 / 128.0);
    }

    #[test]
    fn lossy_encodings_decode_within_tolerance() {
        let vals: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let t = Tensor::from_f32("w", vec![100], &vals);
        for codec in [WireCodec::F16, WireCodec::Bf16, WireCodec::Int8] {
            let c = t.compress(codec, None);
            assert!(!c.encoding().is_dense(), "{codec:?} compresses");
            assert!(c.byte_len() < t.byte_len(), "{codec:?} shrinks bytes");
            let tol = match codec {
                WireCodec::F16 => 0.02,
                WireCodec::Bf16 => 0.16,
                WireCodec::Int8 => 0.08, // range 36.63 / 255 / 2 ≈ 0.072
                _ => unreachable!(),
            };
            for (i, v) in vals.iter().enumerate() {
                assert!(
                    (c.get_f64(i) - *v as f64).abs() <= tol,
                    "{codec:?} elem {i}: {} vs {v}",
                    c.get_f64(i)
                );
            }
            // One-pass fold agrees with per-element access.
            let folded = fold_of(&c);
            for (i, f) in folded.iter().enumerate() {
                assert_eq!(*f, c.get_f64(i), "{codec:?} fold vs get at {i}");
            }
        }
    }

    #[test]
    fn int8_constant_tensor_decodes_exactly() {
        let t = Tensor::from_f32("c", vec![5], &[3.25; 5]);
        let c = t.compress(WireCodec::Int8, None);
        for i in 0..5 {
            assert_eq!(c.get_f64(i), 3.25);
        }
    }

    #[test]
    fn topk_of_sparse_values_is_bit_exact() {
        // 3 of 12 nonzero and k = ceil(12/4) = 3: sparsification of
        // exact values loses nothing.
        let mut vals = vec![0.0f32; 12];
        vals[1] = -7.5;
        vals[4] = f32::from_bits(0x3f80_0001); // oddball bit pattern
        vals[11] = 0.125;
        let t = Tensor::from_f32("g", vec![12], &vals);
        let c = t.compress(WireCodec::TopK, None);
        assert_eq!(c.encoding(), Encoding::TopK { k: 3 });
        assert_eq!(c.byte_len(), 24);
        let back = c.to_f32_vec();
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(fold_of(&c), vals.iter().map(|v| *v as f64).collect::<Vec<_>>());
        // Int8 top-k: same support, quantized values, 5 bytes/kept.
        let q = t.compress(WireCodec::Int8TopK, None);
        assert_eq!(q.byte_len(), 15);
        assert_eq!(q.get_f64(0), 0.0);
        assert!((q.get_f64(1) + 7.5).abs() < 0.05);
    }

    #[test]
    fn topk_keeps_largest_magnitudes_deterministically() {
        let t = Tensor::from_f32("g", vec![8], &[1.0, -9.0, 2.0, 2.0, 0.0, 8.0, -2.0, 0.5]);
        let c = t.compress(WireCodec::TopK, None); // k = 2
        let back = c.to_f32_vec();
        assert_eq!(back, vec![0.0, -9.0, 0.0, 0.0, 0.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn delta_xor_roundtrips_bit_exact() {
        let base = Tensor::from_f32("w", vec![4], &[1.0, -2.0, f32::NAN, 0.25]);
        let new = Tensor::from_f32("w", vec![4], &[1.5, -2.0, 3.0, -0.0]);
        let d = new.compress(WireCodec::Delta, Some((&base, 7)));
        assert_eq!(d.encoding(), Encoding::DeltaXor { base_version: 7 });
        let resolved = d.resolve_delta(&base, 7).unwrap();
        assert!(resolved.bits_equal(&new));
        // Version mismatch is a typed refusal, not silent corruption.
        let err = d.resolve_delta(&base, 8).unwrap_err().to_string();
        assert!(is_unsupported_codec(&err), "typed: {err}");
        // Missing base at compress time falls back to dense passthrough.
        let solo = new.compress(WireCodec::Delta, None);
        assert!(solo.encoding().is_dense());
    }

    #[test]
    fn record_compress_and_resolve() {
        let base = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![3], &[1.0, 2.0, 3.0]),
            Tensor::from_i64("steps", vec![2], &[5, 6]),
        ])
        .unwrap();
        let new = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![3], &[1.5, 2.0, 3.5]),
            Tensor::from_i64("steps", vec![2], &[7, 8]),
        ])
        .unwrap();
        let d = new.compress(WireCodec::Delta, Some((&base, 3)));
        assert!(d.has_delta());
        let resolved = d.resolve_delta(&base, 3).unwrap();
        assert!(resolved.bits_equal(&new));
        assert!(resolved.is_all_dense());
        // Lossy policy skips non-f32 tensors.
        let q = new.compress(WireCodec::Int8, None);
        assert!(!q.get("w").unwrap().encoding().is_dense());
        assert!(q.get("steps").unwrap().encoding().is_dense());
        // to_dense materializes a logically-equal dense record.
        let dense = q.to_dense();
        assert!(dense.is_all_dense());
        assert!(dense.dims_match(&new));
    }

    #[test]
    fn new_encoded_validates_layouts_and_indices() {
        // Wrong payload length for the encoding.
        assert!(Tensor::new_encoded(
            "x",
            DType::F32,
            vec![4],
            Encoding::F16,
            Bytes::from_vec(vec![0u8; 6])
        )
        .is_err());
        assert!(Tensor::new_encoded(
            "x",
            DType::F32,
            vec![4],
            Encoding::F16,
            Bytes::from_vec(vec![0u8; 8])
        )
        .is_ok());
        // Numeric codecs are f32-only.
        assert!(Tensor::new_encoded(
            "x",
            DType::I64,
            vec![4],
            Encoding::Int8 {
                scale: 1.0,
                zero_point: 0.0
            },
            Bytes::from_vec(vec![0u8; 4])
        )
        .is_err());
        // Top-k: k must not exceed elems, indices must be strictly
        // ascending and in bounds.
        let enc = Encoding::TopK { k: 2 };
        let mk = |i0: u32, i1: u32| {
            let mut b = Vec::new();
            b.extend_from_slice(&i0.to_le_bytes());
            b.extend_from_slice(&i1.to_le_bytes());
            b.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
            b.extend_from_slice(&2.0f32.to_bits().to_le_bytes());
            Bytes::from_vec(b)
        };
        assert!(Tensor::new_encoded("x", DType::F32, vec![4], enc, mk(1, 3)).is_ok());
        assert!(Tensor::new_encoded("x", DType::F32, vec![4], enc, mk(3, 1)).is_err());
        assert!(Tensor::new_encoded("x", DType::F32, vec![4], enc, mk(2, 2)).is_err());
        assert!(Tensor::new_encoded("x", DType::F32, vec![4], enc, mk(1, 4)).is_err());
        assert!(Tensor::new_encoded(
            "x",
            DType::F32,
            vec![1],
            Encoding::TopK { k: 2 },
            mk(0, 1)
        )
        .is_err());
    }

    #[test]
    fn wire_codec_names_roundtrip() {
        for c in [
            WireCodec::Identity,
            WireCodec::F16,
            WireCodec::Bf16,
            WireCodec::Int8,
            WireCodec::TopK,
            WireCodec::Int8TopK,
            WireCodec::Delta,
        ] {
            assert_eq!(WireCodec::from_name(c.name()), Some(c));
        }
        assert_eq!(WireCodec::from_name("zstd-v9"), None);
        assert!(WireCodec::Int8.is_lossy());
        assert!(!WireCodec::Delta.is_lossy());
    }

    #[test]
    fn state_record_counters_and_tensors() {
        let mut s = StateRecord::new();
        assert!(s.is_empty());
        assert_eq!(s.bump("rounds_seen", 1), 1);
        assert_eq!(s.bump("rounds_seen", 1), 2);
        assert_eq!(s.get_i64("rounds_seen"), Some(2));
        s.set("name", ConfigValue::Str("node-a".into()));
        assert_eq!(s.get_str("name"), Some("node-a"));
        // Tensor slots replace by name.
        s.set_tensor(Tensor::from_f32("momentum", vec![2], &[1.0, 2.0]));
        s.set_tensor(Tensor::from_f32("momentum", vec![2], &[3.0, 4.0]));
        assert_eq!(s.tensor("momentum").unwrap().get_f64(1), 4.0);
        assert!(s.tensor("absent").is_none());
        assert!(!s.is_empty());
    }
}
