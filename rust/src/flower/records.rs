//! The record model — Flower's `RecordDict` Message API, offline:
//! named, shaped, dtyped [`Tensor`]s bundled into an [`ArrayRecord`],
//! plus metric and config records, bundled into a [`RecordDict`].
//!
//! This replaces the seed's single flat `Vec<f32>` parameter
//! representation everywhere: real models are multi-tensor and
//! multi-dtype, and a flat vector forces full copies on every hop of
//! the six-hop bridge path and makes per-layer strategies, quantized
//! payloads, and partial updates unrepresentable.
//!
//! Tensor payloads are stored as little-endian packed bytes in a shared
//! [`Bytes`] buffer. Decoding a received frame into an `ArrayRecord`
//! performs **zero payload copies**: each tensor borrows the frame's
//! allocation (see `flower::message` and the `record_codec` bench).
//! Element access decodes scalars on the fly — aggregation reads
//! through [`Tensor::get_f64`] and materializes fresh buffers only for
//! its outputs, which is the compute boundary, not the wire.
//!
//! Bit-exactness (the paper's Fig. 5 claim) is byte-exactness here:
//! [`ArrayRecord::bits_equal`] and the derived `PartialEq` compare raw
//! payload bytes, so NaN payloads and signed zeros are preserved
//! end-to-end.

use std::collections::HashMap;

use crate::util::bytes::{Bytes, WireError};

// ---------------------------------------------------------------------------
// Config / metric records (moved here from `message.rs`; re-exported
// there for compatibility)
// ---------------------------------------------------------------------------

/// Values carried in a task's config record (Flower's `ConfigRecord`).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    F64(f64),
    I64(i64),
    Str(String),
    Bool(bool),
}

/// Ordered, key-indexed config entries (Flower's `ConfigRecord`).
///
/// Iteration order is **deterministic** — entries keep their insertion
/// order, which is also the wire encoding order (so re-keying a record
/// never reorders frames). Lookups go through an O(1) key index;
/// [`ConfigRecord::insert`] replaces an existing key **in place**,
/// preserving its position.
///
/// Derefs to the underlying `[(String, ConfigValue)]` slice, so
/// `len()`, `iter()`, indexing, and `for (k, v) in &record` all behave
/// like the `Vec<(String, ConfigValue)>` this type replaced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigRecord {
    entries: Vec<(String, ConfigValue)>,
    /// key -> position of its FIRST occurrence (wire decode may carry
    /// duplicate keys from hostile peers; lookups see the first, and
    /// entries are preserved verbatim for byte-exact re-encoding).
    index: HashMap<String, usize>,
}

impl ConfigRecord {
    pub fn new() -> ConfigRecord {
        ConfigRecord::default()
    }

    /// Build from pairs, preserving order (first occurrence wins the
    /// index on duplicate keys).
    pub fn from_pairs(pairs: impl IntoIterator<Item = (String, ConfigValue)>) -> ConfigRecord {
        let mut rec = ConfigRecord::new();
        for (k, v) in pairs {
            if !rec.index.contains_key(&k) {
                rec.index.insert(k.clone(), rec.entries.len());
            }
            rec.entries.push((k, v));
        }
        rec
    }

    /// Set `key` to `value`: replaces an existing entry in place
    /// (keeping its position — deterministic iteration order), appends
    /// otherwise.
    pub fn insert(&mut self, key: impl Into<String>, value: ConfigValue) {
        let key = key.into();
        match self.index.get(&key) {
            Some(&i) => self.entries[i].1 = value,
            None => {
                self.index.insert(key.clone(), self.entries.len());
                self.entries.push((key, value));
            }
        }
    }

    /// Compat shim for the `Vec` API this type replaced. NOTE the
    /// deliberate semantic upgrade on duplicate keys: where `Vec::push`
    /// appended a shadowed second entry (lookups kept returning the
    /// first), this replaces the existing value in place — the LAST
    /// push wins, and no dead duplicate rides the wire.
    pub fn push(&mut self, pair: (String, ConfigValue)) {
        self.insert(pair.0, pair.1);
    }

    /// Indexed lookup (O(1), first occurrence on duplicate keys).
    pub fn get(&self, key: &str) -> Option<&ConfigValue> {
        self.index.get(key).map(|&i| &self.entries[i].1)
    }

    /// `key` as f64 (F64 direct; I64 cast).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(ConfigValue::F64(x)) => Some(*x),
            Some(ConfigValue::I64(x)) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(ConfigValue::I64(x)) => Some(*x),
            _ => None,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(ConfigValue::Str(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(ConfigValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

impl std::ops::Deref for ConfigRecord {
    type Target = [(String, ConfigValue)];
    fn deref(&self) -> &Self::Target {
        &self.entries
    }
}

impl From<Vec<(String, ConfigValue)>> for ConfigRecord {
    fn from(pairs: Vec<(String, ConfigValue)>) -> ConfigRecord {
        ConfigRecord::from_pairs(pairs)
    }
}

impl FromIterator<(String, ConfigValue)> for ConfigRecord {
    fn from_iter<I: IntoIterator<Item = (String, ConfigValue)>>(iter: I) -> ConfigRecord {
        ConfigRecord::from_pairs(iter)
    }
}

impl<'a> IntoIterator for &'a ConfigRecord {
    type Item = &'a (String, ConfigValue);
    type IntoIter = std::slice::Iter<'a, (String, ConfigValue)>;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter()
    }
}

/// Ordered, key-indexed (name, f64) metrics (Flower's `MetricRecord`).
/// Same shape and guarantees as [`ConfigRecord`]: deterministic
/// (insertion) iteration order — the wire order — with an O(1) key
/// index, dereferencing to the underlying `[(String, f64)]` slice.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricRecord {
    entries: Vec<(String, f64)>,
    index: HashMap<String, usize>,
}

impl MetricRecord {
    pub fn new() -> MetricRecord {
        MetricRecord::default()
    }

    pub fn from_pairs(pairs: impl IntoIterator<Item = (String, f64)>) -> MetricRecord {
        let mut rec = MetricRecord::new();
        for (k, v) in pairs {
            if !rec.index.contains_key(&k) {
                rec.index.insert(k.clone(), rec.entries.len());
            }
            rec.entries.push((k, v));
        }
        rec
    }

    /// Set `key` to `value` (replace in place, or append).
    pub fn insert(&mut self, key: impl Into<String>, value: f64) {
        let key = key.into();
        match self.index.get(&key) {
            Some(&i) => self.entries[i].1 = value,
            None => {
                self.index.insert(key.clone(), self.entries.len());
                self.entries.push((key, value));
            }
        }
    }

    /// Compat shim for the `Vec` API this type replaced (duplicate
    /// keys replace in place — last push wins, see
    /// [`ConfigRecord::push`]).
    pub fn push(&mut self, pair: (String, f64)) {
        self.insert(pair.0, pair.1);
    }

    /// Indexed lookup (O(1)).
    pub fn get(&self, key: &str) -> Option<f64> {
        self.index.get(key).map(|&i| self.entries[i].1)
    }
}

impl std::ops::Deref for MetricRecord {
    type Target = [(String, f64)];
    fn deref(&self) -> &Self::Target {
        &self.entries
    }
}

impl From<Vec<(String, f64)>> for MetricRecord {
    fn from(pairs: Vec<(String, f64)>) -> MetricRecord {
        MetricRecord::from_pairs(pairs)
    }
}

impl FromIterator<(String, f64)> for MetricRecord {
    fn from_iter<I: IntoIterator<Item = (String, f64)>>(iter: I) -> MetricRecord {
        MetricRecord::from_pairs(iter)
    }
}

impl<'a> IntoIterator for &'a MetricRecord {
    type Item = &'a (String, f64);
    type IntoIter = std::slice::Iter<'a, (String, f64)>;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter()
    }
}

#[deprecated(note = "use ConfigRecord::get_f64")]
pub fn config_get_f64(c: &ConfigRecord, key: &str) -> Option<f64> {
    c.get_f64(key)
}

#[deprecated(note = "use ConfigRecord::get_i64")]
pub fn config_get_i64(c: &ConfigRecord, key: &str) -> Option<i64> {
    c.get_i64(key)
}

#[deprecated(note = "use ConfigRecord::get_str")]
pub fn config_get_str<'a>(c: &'a ConfigRecord, key: &str) -> Option<&'a str> {
    c.get_str(key)
}

// ---------------------------------------------------------------------------
// DType
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I64,
    U8,
}

impl DType {
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    pub fn wire_tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I64 => 2,
            DType::U8 => 3,
        }
    }

    pub fn from_wire_tag(tag: u8) -> Result<DType, WireError> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I64,
            3 => DType::U8,
            t => return Err(WireError::BadTag(t)),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I64 => "i64",
            DType::U8 => "u8",
        }
    }
}

// ---------------------------------------------------------------------------
// Tensor
// ---------------------------------------------------------------------------

/// A named, shaped, dtyped tensor whose payload is a little-endian
/// packed byte view into a shared buffer. Cloning is O(1).
#[derive(Clone)]
pub struct Tensor {
    name: String,
    dtype: DType,
    shape: Vec<usize>,
    data: Bytes,
}

fn elems_of(shape: &[usize]) -> usize {
    shape.iter().product::<usize>()
}

impl Tensor {
    /// Wrap an existing byte view. Validates the payload length against
    /// dtype × shape.
    pub fn new(
        name: impl Into<String>,
        dtype: DType,
        shape: Vec<usize>,
        data: Bytes,
    ) -> anyhow::Result<Tensor> {
        let name = name.into();
        let want = elems_of(&shape) * dtype.size_of();
        anyhow::ensure!(
            data.len() == want,
            "tensor '{name}': payload {} bytes, {} {:?} needs {want}",
            data.len(),
            dtype.name(),
            shape
        );
        Ok(Tensor {
            name,
            dtype,
            shape,
            data,
        })
    }

    pub fn from_f32(name: impl Into<String>, shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::F32,
            shape,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_f64(name: impl Into<String>, shape: Vec<usize>, vals: &[f64]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::F64,
            shape,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_i64(name: impl Into<String>, shape: Vec<usize>, vals: &[i64]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        crate::telemetry::bump("records.pack_bytes", buf.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::I64,
            shape,
            data: Bytes::from_vec(buf),
        }
    }

    pub fn from_u8(name: impl Into<String>, shape: Vec<usize>, vals: &[u8]) -> Tensor {
        assert_eq!(elems_of(&shape), vals.len(), "shape/element mismatch");
        crate::telemetry::bump("records.pack_bytes", vals.len() as i64);
        Tensor {
            name: name.into(),
            dtype: DType::U8,
            shape,
            data: Bytes::copy_from_slice(vals),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of elements.
    pub fn elems(&self) -> usize {
        elems_of(&self.shape)
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    /// The raw little-endian payload view (shared, zero-copy).
    pub fn data(&self) -> &Bytes {
        &self.data
    }

    /// Element `i` as f64 (lossless for F32/F64; exact for I64/U8 within
    /// f64's 53-bit integer range).
    #[inline]
    pub fn get_f64(&self, i: usize) -> f64 {
        let s = self.data.as_slice();
        match self.dtype {
            DType::F32 => {
                let o = i * 4;
                f32::from_bits(u32::from_le_bytes([s[o], s[o + 1], s[o + 2], s[o + 3]])) as f64
            }
            DType::F64 => {
                let o = i * 8;
                f64::from_bits(u64::from_le_bytes([
                    s[o],
                    s[o + 1],
                    s[o + 2],
                    s[o + 3],
                    s[o + 4],
                    s[o + 5],
                    s[o + 6],
                    s[o + 7],
                ]))
            }
            DType::I64 => self.get_bits_u64(i) as i64 as f64,
            DType::U8 => s[i] as f64,
        }
    }

    /// Raw 64-bit lane for I64 tensors (used by secure aggregation's
    /// exact wrapping arithmetic). Panics for other dtypes.
    #[inline]
    pub fn get_bits_u64(&self, i: usize) -> u64 {
        assert_eq!(self.dtype, DType::I64, "get_bits_u64 on {:?}", self.dtype);
        let s = self.data.as_slice();
        let o = i * 8;
        u64::from_le_bytes([
            s[o],
            s[o + 1],
            s[o + 2],
            s[o + 3],
            s[o + 4],
            s[o + 5],
            s[o + 6],
            s[o + 7],
        ])
    }

    /// Contiguous iterator over an F32 tensor's elements — the hot
    /// aggregation loops use this instead of per-index [`Tensor::get_f64`]
    /// so the reduction stays a vectorizable linear scan. Panics for
    /// other dtypes.
    pub fn f32_iter(&self) -> impl Iterator<Item = f32> + '_ {
        assert_eq!(self.dtype, DType::F32, "f32_iter on {:?}", self.dtype);
        self.data
            .as_slice()
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
    }

    /// Decode as f32, casting non-f32 dtypes (the canonical flat view).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let n = self.elems();
        let s = self.data.as_slice();
        match self.dtype {
            DType::F32 => s
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                .collect(),
            _ => (0..n).map(|i| self.get_f64(i) as f32).collect(),
        }
    }

    /// Build a tensor of `dtype` from f64 values, casting per dtype
    /// (floats cast; I64 rounds; U8 rounds and saturates).
    pub fn from_f64_values(
        name: impl Into<String>,
        dtype: DType,
        shape: Vec<usize>,
        vals: impl Iterator<Item = f64>,
    ) -> Tensor {
        let name = name.into();
        match dtype {
            DType::F32 => {
                let v: Vec<f32> = vals.map(|x| x as f32).collect();
                Tensor::from_f32(name, shape, &v)
            }
            DType::F64 => {
                let v: Vec<f64> = vals.collect();
                Tensor::from_f64(name, shape, &v)
            }
            DType::I64 => {
                let v: Vec<i64> = vals.map(|x| x.round() as i64).collect();
                Tensor::from_i64(name, shape, &v)
            }
            DType::U8 => {
                let v: Vec<u8> = vals.map(|x| x.round().clamp(0.0, 255.0) as u8).collect();
                Tensor::from_u8(name, shape, &v)
            }
        }
    }

    /// Same name, dtype, and shape (payload not compared).
    pub fn dims_match(&self, other: &Tensor) -> bool {
        self.name == other.name && self.dtype == other.dtype && self.shape == other.shape
    }

    /// Byte-exact equality (name, dtype, shape, payload bits).
    pub fn bits_equal(&self, other: &Tensor) -> bool {
        self.dims_match(other) && self.data == other.data
    }
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.bits_equal(other)
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tensor({} {} {:?}, {} bytes)",
            self.name,
            self.dtype.name(),
            self.shape,
            self.data.len()
        )
    }
}

// ---------------------------------------------------------------------------
// ArrayRecord
// ---------------------------------------------------------------------------

/// Name used by the flat-compat shim for the single tensor wrapping a
/// legacy `Vec<f32>` parameter vector.
pub const FLAT_TENSOR: &str = "parameters";

/// Ordered collection of uniquely-named tensors — Flower's
/// `ArrayRecord`. Order is part of the canonical form: aggregation,
/// masking, and the flat view all iterate in record order, which is why
/// native and bridged runs stay bit-identical.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrayRecord {
    tensors: Vec<Tensor>,
}

impl ArrayRecord {
    pub fn new() -> ArrayRecord {
        ArrayRecord::default()
    }

    pub fn from_tensors(tensors: Vec<Tensor>) -> anyhow::Result<ArrayRecord> {
        // O(n) duplicate detection — this sits on the frame-decode path,
        // where a hostile frame can claim thousands of tensors.
        {
            let mut seen = std::collections::HashSet::with_capacity(tensors.len());
            for t in &tensors {
                anyhow::ensure!(seen.insert(t.name()), "duplicate tensor name '{}'", t.name());
            }
        }
        Ok(ArrayRecord { tensors })
    }

    pub fn push(&mut self, tensor: Tensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.get(tensor.name()).is_none(),
            "duplicate tensor name '{}'",
            tensor.name()
        );
        self.tensors.push(tensor);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name() == name)
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total element count across tensors.
    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }

    /// Total payload bytes across tensors.
    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_len()).sum()
    }

    /// Same tensor names/dtypes/shapes in the same order.
    pub fn dims_match(&self, other: &ArrayRecord) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(other.tensors.iter())
                .all(|(a, b)| a.dims_match(b))
    }

    /// Byte-exact equality across all tensors (NaN-safe — stronger than
    /// float `==`).
    pub fn bits_equal(&self, other: &ArrayRecord) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(other.tensors.iter())
                .all(|(a, b)| a.bits_equal(b))
    }

    // ---------------- flat-compat shim ----------------

    /// Wrap a legacy flat f32 vector as a single-tensor record (the
    /// mechanical migration path for examples/benches).
    pub fn from_flat(vals: &[f32]) -> ArrayRecord {
        ArrayRecord {
            tensors: vec![Tensor::from_f32(FLAT_TENSOR, vec![vals.len()], vals)],
        }
    }

    /// Canonical flattened f32 view: tensors concatenated in record
    /// order, non-f32 dtypes cast. Exact for all-F32 records.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_elems());
        for t in &self.tensors {
            out.extend(t.to_f32_vec());
        }
        out
    }

    /// Rebuild a record with THIS record's structure (names, shapes)
    /// from a flat f32 vector — the exact inverse of [`to_flat`], used
    /// by the train stack to round-trip layer-named tensors through the
    /// flat AOT artifacts.
    ///
    /// Only valid for all-F32 records: a flat f32 intermediate cannot
    /// represent i64/f64 payloads exactly, so rather than silently
    /// corrupting them this errors (the bit-exactness contract).
    ///
    /// [`to_flat`]: ArrayRecord::to_flat
    pub fn from_flat_like(&self, flat: &[f32]) -> anyhow::Result<ArrayRecord> {
        anyhow::ensure!(
            flat.len() == self.total_elems(),
            "flat vector has {} elems, record structure needs {}",
            flat.len(),
            self.total_elems()
        );
        let mut tensors = Vec::with_capacity(self.tensors.len());
        let mut off = 0;
        for t in &self.tensors {
            anyhow::ensure!(
                t.dtype() == DType::F32,
                "from_flat_like: tensor '{}' is {} — a flat f32 view cannot \
                 rebuild non-f32 payloads losslessly",
                t.name(),
                t.dtype().name()
            );
            let n = t.elems();
            tensors.push(Tensor::from_f32(t.name(), t.shape().to_vec(), &flat[off..off + n]));
            off += n;
        }
        Ok(ArrayRecord { tensors })
    }

    /// Element-wise transform preserving structure: `f(tensor_name,
    /// element_index, value)` over every tensor in record order, output
    /// cast back to each tensor's dtype.
    pub fn map_f64(&self, f: impl Fn(&str, usize, f64) -> f64) -> ArrayRecord {
        let tensors = self
            .tensors
            .iter()
            .map(|t| {
                Tensor::from_f64_values(
                    t.name(),
                    t.dtype(),
                    t.shape().to_vec(),
                    (0..t.elems()).map(|i| f(t.name(), i, t.get_f64(i))),
                )
            })
            .collect();
        ArrayRecord { tensors }
    }
}

/// Flat-compat helpers (the migration shim named by the redesign):
/// `compat::from_flat` / `compat::to_flat` are free-function aliases of
/// the [`ArrayRecord`] inherent methods.
pub mod compat {
    use super::ArrayRecord;

    pub fn from_flat(vals: &[f32]) -> ArrayRecord {
        ArrayRecord::from_flat(vals)
    }

    pub fn to_flat(rec: &ArrayRecord) -> Vec<f32> {
        rec.to_flat()
    }
}

// ---------------------------------------------------------------------------
// RecordDict
// ---------------------------------------------------------------------------

/// The full record bundle a message carries: arrays + metrics + configs
/// (Flower's `RecordDict`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecordDict {
    pub arrays: ArrayRecord,
    pub metrics: MetricRecord,
    pub configs: ConfigRecord,
}

impl RecordDict {
    pub fn from_arrays(arrays: ArrayRecord) -> RecordDict {
        RecordDict {
            arrays,
            metrics: MetricRecord::new(),
            configs: ConfigRecord::new(),
        }
    }

    pub fn from_configs(configs: ConfigRecord) -> RecordDict {
        RecordDict {
            arrays: ArrayRecord::new(),
            metrics: MetricRecord::new(),
            configs,
        }
    }
}

// ---------------------------------------------------------------------------
// StateRecord
// ---------------------------------------------------------------------------

/// Per-node mutable state that survives across rounds (Flower's
/// `Context.state`). A SuperNode keeps one per run and hands it to every
/// message handler — this is what makes stateful clients (counters,
/// personalization layers, warm optimizer state) possible without any
/// wire traffic: the state never leaves the node.
///
/// Scalar entries live in a [`ConfigRecord`]; tensor entries (e.g. a
/// warm optimizer moment) are name-keyed with replace-on-set semantics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StateRecord {
    configs: ConfigRecord,
    tensors: Vec<Tensor>,
}

impl StateRecord {
    pub fn new() -> StateRecord {
        StateRecord::default()
    }

    /// Set a scalar entry (replace or append, like
    /// [`ConfigRecord::insert`]).
    pub fn set(&mut self, key: impl Into<String>, value: ConfigValue) {
        self.configs.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&ConfigValue> {
        self.configs.get(key)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.configs.get_f64(key)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.configs.get_i64(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.configs.get_str(key)
    }

    /// Increment the I64 counter at `key` by `by` (missing counts as 0)
    /// and return the new value — the one-liner for "how many times has
    /// this node seen X".
    pub fn bump(&mut self, key: impl Into<String>, by: i64) -> i64 {
        let key = key.into();
        let next = self.configs.get_i64(&key).unwrap_or(0) + by;
        self.configs.insert(key, ConfigValue::I64(next));
        next
    }

    /// Store a tensor under its name (replacing any previous tensor of
    /// that name — state is a map, not a log).
    pub fn set_tensor(&mut self, tensor: Tensor) {
        match self.tensors.iter_mut().find(|t| t.name() == tensor.name()) {
            Some(slot) => *slot = tensor,
            None => self.tensors.push(tensor),
        }
    }

    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name() == name)
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty() && self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_record() -> ArrayRecord {
        ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2, 2], &[1.0, -2.0, 3.5, 0.25]),
            Tensor::from_f64("bias", vec![3], &[1e-12, -4.0, 2.5]),
            Tensor::from_i64("steps", vec![2], &[-7, 1 << 40]),
            Tensor::from_u8("mask", vec![4], &[0, 1, 254, 255]),
        ])
        .unwrap()
    }

    #[test]
    fn dtype_sizes_and_tags_roundtrip() {
        for d in [DType::F32, DType::F64, DType::I64, DType::U8] {
            assert_eq!(DType::from_wire_tag(d.wire_tag()).unwrap(), d);
            assert!(d.size_of() > 0);
        }
        assert!(DType::from_wire_tag(9).is_err());
    }

    #[test]
    fn tensor_element_access() {
        let r = mixed_record();
        assert_eq!(r.get("w").unwrap().get_f64(2), 3.5);
        assert_eq!(r.get("bias").unwrap().get_f64(1), -4.0);
        assert_eq!(r.get("steps").unwrap().get_f64(0), -7.0);
        assert_eq!(r.get("steps").unwrap().get_f64(1), (1u64 << 40) as f64);
        assert_eq!(r.get("mask").unwrap().get_f64(3), 255.0);
        assert_eq!(r.total_elems(), 4 + 3 + 2 + 4);
        assert_eq!(r.total_bytes(), 16 + 24 + 16 + 4);
    }

    #[test]
    fn tensor_new_validates_length() {
        let data = Bytes::from_vec(vec![0u8; 12]);
        assert!(Tensor::new("x", DType::F32, vec![3], data.clone()).is_ok());
        assert!(Tensor::new("x", DType::F32, vec![4], data.clone()).is_err());
        assert!(Tensor::new("x", DType::F64, vec![3], data).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut r = ArrayRecord::from_flat(&[1.0]);
        assert!(r.push(Tensor::from_f32(FLAT_TENSOR, vec![1], &[2.0])).is_err());
        assert!(r.push(Tensor::from_f32("other", vec![1], &[2.0])).is_ok());
    }

    #[test]
    fn flat_roundtrip_exact_for_f32() {
        let vals = [0.0f32, -0.0, f32::NAN, 1e-40, f32::MAX];
        let rec = ArrayRecord::from_flat(&vals);
        let back = rec.to_flat();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Structure-preserving rebuild.
        let rebuilt = rec.from_flat_like(&back).unwrap();
        assert!(rebuilt.bits_equal(&rec));
    }

    #[test]
    fn from_flat_like_validates_length_and_dtype() {
        let rec = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("w", vec![2, 2], &[1.0; 4]),
            Tensor::from_f32("b", vec![3], &[2.0; 3]),
        ])
        .unwrap();
        assert!(rec.from_flat_like(&[0.0; 3]).is_err(), "length mismatch");
        let ok = rec.from_flat_like(&[9.0; 7]).unwrap();
        assert!(ok.dims_match(&rec));
        assert_eq!(ok.get("b").unwrap().get_f64(0), 9.0);
        // Non-f32 structures refuse the lossy flat round-trip.
        assert!(mixed_record()
            .from_flat_like(&vec![1.0; mixed_record().total_elems()])
            .is_err());
    }

    #[test]
    fn map_preserves_structure_and_dtypes() {
        let rec = mixed_record();
        let doubled = rec.map_f64(|_, _, v| v * 2.0);
        assert!(doubled.dims_match(&rec));
        assert_eq!(doubled.get("w").unwrap().get_f64(0), 2.0);
        assert_eq!(doubled.get("steps").unwrap().get_f64(0), -14.0);
        // U8 saturates.
        assert_eq!(doubled.get("mask").unwrap().get_f64(3), 255.0);
    }

    #[test]
    fn bits_equal_nan_safe() {
        let a = ArrayRecord::from_flat(&[f32::NAN, -0.0]);
        let b = ArrayRecord::from_flat(&[f32::NAN, -0.0]);
        let c = ArrayRecord::from_flat(&[f32::NAN, 0.0]);
        assert!(a.bits_equal(&b));
        assert_eq!(a, b);
        assert!(!a.bits_equal(&c), "-0.0 and 0.0 differ bitwise");
    }

    #[test]
    fn dims_match_ignores_payload() {
        let a = ArrayRecord::from_flat(&[1.0, 2.0]);
        let b = ArrayRecord::from_flat(&[3.0, 4.0]);
        assert!(a.dims_match(&b));
        assert!(!a.bits_equal(&b));
        let c = ArrayRecord::from_flat(&[1.0]);
        assert!(!a.dims_match(&c));
    }

    #[test]
    fn compat_shim_is_mechanical() {
        let flat = vec![1.0f32, 2.0, 3.0];
        let rec = compat::from_flat(&flat);
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.tensors()[0].name(), FLAT_TENSOR);
        assert_eq!(compat::to_flat(&rec), flat);
    }

    #[test]
    fn config_record_indexed_get_and_in_place_insert() {
        let mut c = ConfigRecord::from_pairs(vec![
            ("lr".to_string(), ConfigValue::F64(0.1)),
            ("mode".to_string(), ConfigValue::Str("iid".into())),
            ("epochs".to_string(), ConfigValue::I64(2)),
        ]);
        assert_eq!(c.get_f64("lr"), Some(0.1));
        assert_eq!(c.get_f64("epochs"), Some(2.0), "I64 casts for get_f64");
        assert_eq!(c.get_i64("epochs"), Some(2));
        assert_eq!(c.get_str("mode"), Some("iid"));
        assert_eq!(c.get("missing"), None);
        // Replace keeps the key's position — iteration order is
        // deterministic under re-keying.
        c.insert("mode", ConfigValue::Str("skew".into()));
        let keys: Vec<&str> = c.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["lr", "mode", "epochs"]);
        assert_eq!(c.get_str("mode"), Some("skew"));
        assert_eq!(c.len(), 3);
        // Append lands at the end.
        c.push(("new".to_string(), ConfigValue::Bool(true)));
        assert_eq!(c.get_bool("new"), Some(true));
        assert_eq!(c.last().unwrap().0, "new");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_config_shims_still_work() {
        let c = ConfigRecord::from_pairs(vec![
            ("lr".to_string(), ConfigValue::F64(0.5)),
            ("mode".to_string(), ConfigValue::Str("iid".into())),
        ]);
        assert_eq!(config_get_f64(&c, "lr"), Some(0.5));
        assert_eq!(config_get_i64(&c, "lr"), None);
        assert_eq!(config_get_str(&c, "mode"), Some("iid"));
    }

    #[test]
    fn metric_record_indexed_and_ordered() {
        let mut m = MetricRecord::from_pairs(vec![
            ("loss".to_string(), 0.5),
            ("accuracy".to_string(), 0.9),
        ]);
        assert_eq!(m.get("accuracy"), Some(0.9));
        m.insert("loss", 0.25);
        assert_eq!(m.get("loss"), Some(0.25));
        let keys: Vec<&str> = m.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["loss", "accuracy"], "replace keeps position");
        // Slice view works like the old Vec.
        assert_eq!(m[0].1, 0.25);
        let collected: MetricRecord = vec![("a".to_string(), 1.0)].into_iter().collect();
        assert_eq!(collected.get("a"), Some(1.0));
    }

    #[test]
    fn state_record_counters_and_tensors() {
        let mut s = StateRecord::new();
        assert!(s.is_empty());
        assert_eq!(s.bump("rounds_seen", 1), 1);
        assert_eq!(s.bump("rounds_seen", 1), 2);
        assert_eq!(s.get_i64("rounds_seen"), Some(2));
        s.set("name", ConfigValue::Str("node-a".into()));
        assert_eq!(s.get_str("name"), Some("node-a"));
        // Tensor slots replace by name.
        s.set_tensor(Tensor::from_f32("momentum", vec![2], &[1.0, 2.0]));
        s.set_tensor(Tensor::from_f32("momentum", vec![2], &[3.0, 4.0]));
        assert_eq!(s.tensor("momentum").unwrap().get_f64(1), 4.0);
        assert!(s.tensor("absent").is_none());
        assert!(!s.is_empty());
    }
}
