//! The FedOpt family (Reddi et al., 2021): FedAdam, FedAdagrad, FedYogi.
//! The server treats `mean(client updates) - current` as a pseudo-
//! gradient and applies an adaptive optimizer step, per tensor —
//! optimizer state (first/second moments) is kept per tensor name.
//! Paper Listing 1 builds its ServerApp with `FedAdam(...)`.

use std::collections::HashMap;

use super::{Aggregator, FitAgg, FitRes, SortedBuffer, Strategy};
use crate::flower::records::{ArrayRecord, DType, Tensor};

#[derive(Clone, Copy, Debug)]
pub struct FedOptConfig {
    pub server_lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    /// Adaptivity floor (Reddi et al.'s tau).
    pub tau: f64,
}

impl Default for FedOptConfig {
    fn default() -> Self {
        // Reddi et al. use eta=1e-1..1e-2 and tau=1e-3 on their tasks;
        // with our small models and few clients a tau that low makes the
        // early update ~sign-SGD with step=server_lr on every coordinate,
        // which diverges the quickstart CNN. tau=1e-2 keeps the update
        // proportional to the pseudo-gradient at small magnitudes.
        Self {
            server_lr: 0.05,
            beta1: 0.9,
            beta2: 0.99,
            tau: 1e-2,
        }
    }
}

enum Variant {
    Adam,
    Adagrad,
    Yogi,
}

/// Per-tensor optimizer state.
struct Moments {
    m: Vec<f64>,
    v: Vec<f64>,
}

struct FedOpt {
    agg: Aggregator,
    cfg: FedOptConfig,
    variant: Variant,
    state: HashMap<String, Moments>,
}

impl FedOpt {
    fn step(
        &mut self,
        current: &ArrayRecord,
        results: &[FitRes],
    ) -> anyhow::Result<ArrayRecord> {
        let mean = self.agg.weighted_mean(results)?;
        anyhow::ensure!(
            mean.dims_match(current),
            "aggregated record structure differs from current"
        );
        let mut tensors = Vec::with_capacity(current.len());
        for (cur, avg) in current.tensors().iter().zip(mean.tensors().iter()) {
            let n = cur.elems();
            let st = self
                .state
                .entry(cur.name().to_string())
                .or_insert_with(|| Moments {
                    m: Vec::new(),
                    v: Vec::new(),
                });
            if st.m.len() != n {
                st.m = vec![0.0; n];
                st.v = vec![self.cfg.tau * self.cfg.tau; n];
            }
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                // Ascent pseudo-gradient toward the client mean.
                let d = avg.get_f64(i) - cur.get_f64(i);
                st.m[i] = self.cfg.beta1 * st.m[i] + (1.0 - self.cfg.beta1) * d;
                let d2 = d * d;
                st.v[i] = match self.variant {
                    Variant::Adam => self.cfg.beta2 * st.v[i] + (1.0 - self.cfg.beta2) * d2,
                    Variant::Adagrad => st.v[i] + d2,
                    Variant::Yogi => {
                        st.v[i] - (1.0 - self.cfg.beta2) * d2 * (st.v[i] - d2).signum()
                    }
                };
                let step = self.cfg.server_lr * st.m[i] / (st.v[i].sqrt() + self.cfg.tau);
                out.push(cur.get_f64(i) + step);
            }
            tensors.push(Tensor::from_f64_values(
                cur.name(),
                cur.dtype(),
                cur.shape().to_vec(),
                out.into_iter(),
            ));
        }
        Ok(ArrayRecord::from_tensors(tensors)?)
    }

    /// Moments per tensor name as `m:{name}` / `v:{name}` F64 tensors
    /// in sorted-name order (f64 payloads — export -> import is
    /// bit-exact).
    fn export_state(&self) -> Option<ArrayRecord> {
        let mut names: Vec<&String> = self.state.keys().collect();
        names.sort();
        let mut tensors = Vec::with_capacity(names.len() * 2);
        for name in names {
            let st = &self.state[name];
            tensors.push(Tensor::from_f64_values(
                &format!("m:{name}"),
                DType::F64,
                vec![st.m.len()],
                st.m.iter().copied(),
            ));
            tensors.push(Tensor::from_f64_values(
                &format!("v:{name}"),
                DType::F64,
                vec![st.v.len()],
                st.v.iter().copied(),
            ));
        }
        ArrayRecord::from_tensors(tensors).ok()
    }

    fn import_state(&mut self, state: &ArrayRecord) -> anyhow::Result<()> {
        self.state.clear();
        for t in state.tensors() {
            let (kind, name) = t
                .name()
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("unrecognized moment tensor '{}'", t.name()))?;
            let vals: Vec<f64> = (0..t.elems()).map(|i| t.get_f64(i)).collect();
            let st = self
                .state
                .entry(name.to_string())
                .or_insert_with(|| Moments {
                    m: Vec::new(),
                    v: Vec::new(),
                });
            match kind {
                "m" => st.m = vals,
                "v" => st.v = vals,
                _ => anyhow::bail!("unrecognized moment tensor '{}'", t.name()),
            }
        }
        Ok(())
    }
}

macro_rules! fedopt_strategy {
    ($name:ident, $variant:expr, $label:literal) => {
        pub struct $name(FedOpt);

        impl $name {
            pub fn new(agg: Aggregator, cfg: FedOptConfig) -> Self {
                Self(FedOpt {
                    agg,
                    cfg,
                    variant: $variant,
                    state: HashMap::new(),
                })
            }
        }

        impl Strategy for $name {
            fn name(&self) -> &'static str {
                $label
            }

            fn begin_fit(&mut self, _round: u64, current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
                let current = current.clone();
                Box::new(SortedBuffer::new(move |results: &[FitRes]| {
                    self.0.step(&current, results)
                }))
            }

            fn export_state(&self) -> Option<ArrayRecord> {
                self.0.export_state()
            }

            fn import_state(&mut self, state: &ArrayRecord) -> anyhow::Result<()> {
                self.0.import_state(state)
            }
        }
    };
}

fedopt_strategy!(FedAdam, Variant::Adam, "fedadam");
fedopt_strategy!(FedAdagrad, Variant::Adagrad, "fedadagrad");
fedopt_strategy!(FedYogi, Variant::Yogi, "fedyogi");

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    fn step_once<S: Strategy>(s: &mut S, x: &ArrayRecord, target: f32) -> Vec<f32> {
        s.aggregate_fit(1, x, &[fit(1, vec![target; x.total_elems()], 1)])
            .unwrap()
            .to_flat()
    }

    #[test]
    fn fedadam_moves_toward_client_mean() {
        let mut s = FedAdam::new(Aggregator::host(), FedOptConfig::default());
        let x0 = ArrayRecord::from_flat(&[0.0, 0.0]);
        let x1 = step_once(&mut s, &x0, 1.0);
        assert!(x1.iter().all(|&x| x > 0.0 && x <= 1.0), "{x1:?}");
    }

    #[test]
    fn fedadam_converges_on_fixed_target() {
        let mut s = FedAdam::new(
            Aggregator::host(),
            FedOptConfig {
                server_lr: 0.3,
                ..Default::default()
            },
        );
        let mut x = ArrayRecord::from_flat(&[0.0]);
        for round in 1..=60 {
            x = s.aggregate_fit(round, &x, &[fit(1, vec![2.0], 4)]).unwrap();
        }
        let flat = x.to_flat();
        assert!((flat[0] - 2.0).abs() < 0.2, "{flat:?}");
    }

    #[test]
    fn fedadagrad_steps_shrink() {
        // beta1=0 isolates the accumulating-denominator behaviour from
        // first-moment warmup.
        let mut s = FedAdagrad::new(
            Aggregator::host(),
            FedOptConfig {
                beta1: 0.0,
                ..Default::default()
            },
        );
        let x0 = ArrayRecord::from_flat(&[0.0]);
        let x1 = s.aggregate_fit(1, &x0, &[fit(1, vec![1.0], 1)]).unwrap();
        let step1 = x1.to_flat()[0] - x0.to_flat()[0];
        let x2 = s.aggregate_fit(2, &x1, &[fit(1, vec![1.0], 1)]).unwrap();
        let step2 = x2.to_flat()[0] - x1.to_flat()[0];
        assert!(step2.abs() < step1.abs(), "{step1} then {step2}");
    }

    #[test]
    fn fedyogi_bounded_update() {
        let mut s = FedYogi::new(Aggregator::host(), FedOptConfig::default());
        let x = ArrayRecord::from_flat(&[0.0; 3]);
        let x1 = step_once(&mut s, &x, 10.0);
        // Adaptive normalization keeps the first step ~server_lr-scale.
        assert!(x1.iter().all(|&v| v.abs() < 1.0), "{x1:?}");
    }

    #[test]
    fn per_tensor_state_is_independent() {
        use crate::flower::records::Tensor;
        // Two tensors with very different pseudo-gradients must keep
        // separate moment estimates (state keyed by tensor name).
        let current = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("a", vec![1], &[0.0]),
            Tensor::from_f32("b", vec![1], &[0.0]),
        ])
        .unwrap();
        let update = ArrayRecord::from_tensors(vec![
            Tensor::from_f32("a", vec![1], &[1.0]),
            Tensor::from_f32("b", vec![1], &[-1.0]),
        ])
        .unwrap();
        let mut s = FedAdam::new(Aggregator::host(), FedOptConfig::default());
        let res = [super::super::FitRes {
            node_id: 1,
            parameters: update,
            num_examples: 1,
            metrics: crate::flower::records::MetricRecord::new(),
        }];
        let out = s.aggregate_fit(1, &current, &res).unwrap();
        let a = out.get("a").unwrap().get_f64(0);
        let b = out.get("b").unwrap().get_f64(0);
        assert!(a > 0.0 && b < 0.0, "a={a} b={b}");
        assert!((a + b).abs() < 1e-12, "symmetric gradients, symmetric steps");
    }

    #[test]
    fn all_variants_are_deterministic() {
        for mk in 0..3 {
            let make = |agg| -> Box<dyn Strategy> {
                match mk {
                    0 => Box::new(FedAdam::new(agg, FedOptConfig::default())),
                    1 => Box::new(FedAdagrad::new(agg, FedOptConfig::default())),
                    _ => Box::new(FedYogi::new(agg, FedOptConfig::default())),
                }
            };
            let run = || {
                let mut s = make(Aggregator::host());
                let mut x = ArrayRecord::from_flat(&[0.5f32, -0.5]);
                for round in 1..=5 {
                    x = s
                        .aggregate_fit(
                            round,
                            &x,
                            &[fit(1, vec![1.0, -1.0], 2), fit(2, vec![0.0, 0.0], 1)],
                        )
                        .unwrap();
                }
                x
            };
            let a: Vec<u32> = run().to_flat().iter().map(|f| f.to_bits()).collect();
            let b: Vec<u32> = run().to_flat().iter().map(|f| f.to_bits()).collect();
            assert_eq!(a, b);
        }
    }
}
