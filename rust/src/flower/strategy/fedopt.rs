//! The FedOpt family (Reddi et al., 2021): FedAdam, FedAdagrad, FedYogi.
//! The server treats `mean(client updates) - current` as a pseudo-
//! gradient and applies an adaptive optimizer step. Paper Listing 1
//! builds its ServerApp with `FedAdam(...)`.

use super::{Aggregator, FitRes, Strategy};

#[derive(Clone, Copy, Debug)]
pub struct FedOptConfig {
    pub server_lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    /// Adaptivity floor (Reddi et al.'s tau).
    pub tau: f64,
}

impl Default for FedOptConfig {
    fn default() -> Self {
        // Reddi et al. use eta=1e-1..1e-2 and tau=1e-3 on their tasks;
        // with our small models and few clients a tau that low makes the
        // early update ~sign-SGD with step=server_lr on every coordinate,
        // which diverges the quickstart CNN. tau=1e-2 keeps the update
        // proportional to the pseudo-gradient at small magnitudes.
        Self {
            server_lr: 0.05,
            beta1: 0.9,
            beta2: 0.99,
            tau: 1e-2,
        }
    }
}

enum Variant {
    Adam,
    Adagrad,
    Yogi,
}

struct FedOpt {
    agg: Aggregator,
    cfg: FedOptConfig,
    variant: Variant,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl FedOpt {
    fn step(&mut self, current: &[f32], results: &[FitRes]) -> anyhow::Result<Vec<f32>> {
        let mean = self.agg.weighted_mean(results)?;
        let n = current.len();
        if self.m.len() != n {
            self.m = vec![0.0; n];
            self.v = vec![self.cfg.tau * self.cfg.tau; n];
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // Ascent pseudo-gradient toward the client mean.
            let d = mean[i] as f64 - current[i] as f64;
            self.m[i] = self.cfg.beta1 * self.m[i] + (1.0 - self.cfg.beta1) * d;
            let d2 = d * d;
            self.v[i] = match self.variant {
                Variant::Adam => self.cfg.beta2 * self.v[i] + (1.0 - self.cfg.beta2) * d2,
                Variant::Adagrad => self.v[i] + d2,
                Variant::Yogi => {
                    self.v[i]
                        - (1.0 - self.cfg.beta2) * d2 * (self.v[i] - d2).signum()
                }
            };
            let step = self.cfg.server_lr * self.m[i] / (self.v[i].sqrt() + self.cfg.tau);
            out.push((current[i] as f64 + step) as f32);
        }
        Ok(out)
    }
}

macro_rules! fedopt_strategy {
    ($name:ident, $variant:expr, $label:literal) => {
        pub struct $name(FedOpt);

        impl $name {
            pub fn new(agg: Aggregator, cfg: FedOptConfig) -> Self {
                Self(FedOpt {
                    agg,
                    cfg,
                    variant: $variant,
                    m: Vec::new(),
                    v: Vec::new(),
                })
            }
        }

        impl Strategy for $name {
            fn name(&self) -> &'static str {
                $label
            }

            fn aggregate_fit(
                &mut self,
                _round: u64,
                current: &[f32],
                results: &[FitRes],
            ) -> anyhow::Result<Vec<f32>> {
                self.0.step(current, results)
            }
        }
    };
}

fedopt_strategy!(FedAdam, Variant::Adam, "fedadam");
fedopt_strategy!(FedAdagrad, Variant::Adagrad, "fedadagrad");
fedopt_strategy!(FedYogi, Variant::Yogi, "fedyogi");

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    fn step_once<S: Strategy>(s: &mut S, x: &[f32], target: f32) -> Vec<f32> {
        s.aggregate_fit(1, x, &[fit(1, vec![target; x.len()], 1)])
            .unwrap()
    }

    #[test]
    fn fedadam_moves_toward_client_mean() {
        let mut s = FedAdam::new(Aggregator::host(), FedOptConfig::default());
        let x0 = vec![0.0f32, 0.0];
        let x1 = step_once(&mut s, &x0, 1.0);
        assert!(x1.iter().all(|&x| x > 0.0 && x <= 1.0), "{x1:?}");
    }

    #[test]
    fn fedadam_converges_on_fixed_target() {
        let mut s = FedAdam::new(
            Aggregator::host(),
            FedOptConfig {
                server_lr: 0.3,
                ..Default::default()
            },
        );
        let mut x = vec![0.0f32];
        for round in 1..=60 {
            x = s.aggregate_fit(round, &x, &[fit(1, vec![2.0], 4)]).unwrap();
        }
        assert!((x[0] - 2.0).abs() < 0.2, "{x:?}");
    }

    #[test]
    fn fedadagrad_steps_shrink() {
        // beta1=0 isolates the accumulating-denominator behaviour from
        // first-moment warmup.
        let mut s = FedAdagrad::new(
            Aggregator::host(),
            FedOptConfig {
                beta1: 0.0,
                ..Default::default()
            },
        );
        let mut x = vec![0.0f32];
        let x1 = s.aggregate_fit(1, &x, &[fit(1, vec![1.0], 1)]).unwrap();
        let step1 = x1[0] - x[0];
        x = x1;
        let x2 = s.aggregate_fit(2, &x, &[fit(1, vec![1.0], 1)]).unwrap();
        let step2 = x2[0] - x[0];
        assert!(step2.abs() < step1.abs(), "{step1} then {step2}");
    }

    #[test]
    fn fedyogi_bounded_update() {
        let mut s = FedYogi::new(Aggregator::host(), FedOptConfig::default());
        let x = vec![0.0f32; 3];
        let x1 = step_once(&mut s, &x, 10.0);
        // Adaptive normalization keeps the first step ~server_lr-scale.
        assert!(x1.iter().all(|&v| v.abs() < 1.0), "{x1:?}");
    }

    #[test]
    fn all_variants_are_deterministic() {
        for mk in 0..3 {
            let make = |agg| -> Box<dyn Strategy> {
                match mk {
                    0 => Box::new(FedAdam::new(agg, FedOptConfig::default())),
                    1 => Box::new(FedAdagrad::new(agg, FedOptConfig::default())),
                    _ => Box::new(FedYogi::new(agg, FedOptConfig::default())),
                }
            };
            let run = || {
                let mut s = make(Aggregator::host());
                let mut x = vec![0.5f32, -0.5];
                for round in 1..=5 {
                    x = s
                        .aggregate_fit(
                            round,
                            &x,
                            &[fit(1, vec![1.0, -1.0], 2), fit(2, vec![0.0, 0.0], 1)],
                        )
                        .unwrap();
                }
                x
            };
            let a: Vec<u32> = run().iter().map(|f| f.to_bits()).collect();
            let b: Vec<u32> = run().iter().map(|f| f.to_bits()).collect();
            assert_eq!(a, b);
        }
    }
}
