//! FedProx (Li et al., 2020): FedAvg aggregation + a proximal term μ on
//! the client objective. The server's role is to push μ in the fit
//! config; proximal correction happens client-side (see
//! `train::trainer`, which composes the correction exactly around the
//! AOT SGD step).

use super::{Aggregator, FitAgg, FitRes, SortedBuffer, Strategy};
use crate::flower::message::{ConfigRecord, ConfigValue};
use crate::flower::records::ArrayRecord;

pub struct FedProx {
    agg: Aggregator,
    mu: f64,
}

impl FedProx {
    pub fn new(agg: Aggregator, mu: f64) -> Self {
        Self { agg, mu }
    }
}

impl Strategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn configure_fit(&mut self, _round: u64) -> ConfigRecord {
        ConfigRecord::from_pairs(vec![(
            "proximal_mu".to_string(),
            ConfigValue::F64(self.mu),
        )])
    }

    fn begin_fit(&mut self, _round: u64, _current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        let agg = self.agg.clone();
        Box::new(SortedBuffer::new(move |results: &[FitRes]| {
            agg.weighted_mean(results)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    #[test]
    fn pushes_mu_and_averages() {
        let mut s = FedProx::new(Aggregator::host(), 0.01);
        let cfg = s.configure_fit(1);
        assert_eq!(cfg.get_f64("proximal_mu"), Some(0.01));
        let out = s
            .aggregate_fit(
                1,
                &ArrayRecord::from_flat(&[0.0]),
                &[fit(1, vec![2.0], 1), fit(2, vec![4.0], 1)],
            )
            .unwrap();
        assert_eq!(out.to_flat(), vec![3.0]);
    }
}
