//! Byzantine-robust aggregation: coordinate-wise median and trimmed
//! mean (Yin et al., 2018) — part of Flower's strategy zoo that FLARE
//! users gain access to through the integration (paper §6 "direct
//! utilization of FL algorithms ... from Flower").

use super::{FitRes, Strategy};

/// Coordinate-wise median (unweighted — robustness over efficiency).
pub struct FedMedian;

impl Strategy for FedMedian {
    fn name(&self) -> &'static str {
        "fedmedian"
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        _current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!results.is_empty(), "no results");
        let n = results[0].parameters.len();
        let mut out = Vec::with_capacity(n);
        let mut col = Vec::with_capacity(results.len());
        for i in 0..n {
            col.clear();
            for r in results {
                anyhow::ensure!(r.parameters.len() == n, "length mismatch");
                col.push(r.parameters[i]);
            }
            col.sort_by(f32::total_cmp);
            let k = col.len();
            out.push(if k % 2 == 1 {
                col[k / 2]
            } else {
                (col[k / 2 - 1] + col[k / 2]) / 2.0
            });
        }
        Ok(out)
    }
}

/// Coordinate-wise trimmed mean: drop the `trim` largest and smallest
/// values per coordinate, average the rest.
pub struct TrimmedMean {
    pub trim: usize,
}

impl Strategy for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        _current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            results.len() > 2 * self.trim,
            "need more than {} clients to trim {} each side",
            2 * self.trim,
            self.trim
        );
        let n = results[0].parameters.len();
        let mut out = Vec::with_capacity(n);
        let mut col = Vec::with_capacity(results.len());
        for i in 0..n {
            col.clear();
            for r in results {
                anyhow::ensure!(r.parameters.len() == n, "length mismatch");
                col.push(r.parameters[i]);
            }
            col.sort_by(f32::total_cmp);
            let kept = &col[self.trim..col.len() - self.trim];
            out.push(kept.iter().map(|x| *x as f64).sum::<f64>() as f32 / kept.len() as f32);
        }
        Ok(out)
    }
}

/// Krum (Blanchard et al., 2017): pick the single client update whose
/// summed squared distance to its n-f-2 nearest neighbours is smallest
/// (tolerates up to `f` Byzantine clients).
pub struct Krum {
    /// Assumed maximum number of Byzantine clients.
    pub f: usize,
}

impl Strategy for Krum {
    fn name(&self) -> &'static str {
        "krum"
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        _current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>> {
        let n = results.len();
        anyhow::ensure!(
            n > 2 * self.f + 2,
            "krum needs n > 2f+2 (n={n}, f={})",
            self.f
        );
        let dim = results[0].parameters.len();
        for r in results {
            anyhow::ensure!(r.parameters.len() == dim, "length mismatch");
        }
        // Pairwise squared distances.
        let mut d2 = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let dist: f64 = results[i]
                    .parameters
                    .iter()
                    .zip(results[j].parameters.iter())
                    .map(|(a, b)| {
                        let d = *a as f64 - *b as f64;
                        d * d
                    })
                    .sum();
                d2[i][j] = dist;
                d2[j][i] = dist;
            }
        }
        // Score = sum of the n-f-2 smallest distances to others.
        let keep = n - self.f - 2;
        let mut best = (f64::INFINITY, 0usize);
        for i in 0..n {
            let mut ds: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| d2[i][j]).collect();
            ds.sort_by(f64::total_cmp);
            let score: f64 = ds.iter().take(keep).sum();
            if score < best.0 {
                best = (score, i);
            }
        }
        Ok(results[best.1].parameters.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    #[test]
    fn median_ignores_outlier() {
        let mut s = FedMedian;
        let out = s
            .aggregate_fit(
                1,
                &[0.0],
                &[
                    fit(1, vec![1.0], 1),
                    fit(2, vec![2.0], 1),
                    fit(3, vec![1000.0], 1), // byzantine
                ],
            )
            .unwrap();
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn median_even_count_averages_middle() {
        let mut s = FedMedian;
        let out = s
            .aggregate_fit(
                1,
                &[0.0],
                &[
                    fit(1, vec![1.0], 1),
                    fit(2, vec![2.0], 1),
                    fit(3, vec![3.0], 1),
                    fit(4, vec![4.0], 1),
                ],
            )
            .unwrap();
        assert_eq!(out, vec![2.5]);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let mut s = TrimmedMean { trim: 1 };
        let out = s
            .aggregate_fit(
                1,
                &[0.0],
                &[
                    fit(1, vec![-100.0], 1),
                    fit(2, vec![1.0], 1),
                    fit(3, vec![3.0], 1),
                    fit(4, vec![100.0], 1),
                ],
            )
            .unwrap();
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn trimmed_mean_needs_enough_clients() {
        let mut s = TrimmedMean { trim: 1 };
        assert!(s
            .aggregate_fit(1, &[0.0], &[fit(1, vec![1.0], 1), fit(2, vec![2.0], 1)])
            .is_err());
    }

    #[test]
    fn krum_picks_a_clustered_honest_update() {
        let mut s = Krum { f: 1 };
        // 4 honest updates near (1,1); 1 Byzantine at (100, -100).
        let results = vec![
            fit(1, vec![1.0, 1.0], 1),
            fit(2, vec![1.1, 0.9], 1),
            fit(3, vec![0.9, 1.1], 1),
            fit(4, vec![1.05, 1.0], 1),
            fit(5, vec![100.0, -100.0], 1),
        ];
        let out = s.aggregate_fit(1, &[0.0, 0.0], &results).unwrap();
        assert!(out[0] < 2.0 && out[1] > 0.0, "picked byzantine: {out:?}");
    }

    #[test]
    fn krum_requires_enough_clients() {
        let mut s = Krum { f: 1 };
        let results = vec![
            fit(1, vec![1.0], 1),
            fit(2, vec![1.0], 1),
            fit(3, vec![1.0], 1),
            fit(4, vec![1.0], 1),
        ];
        // n=4 is NOT > 2f+2=4.
        assert!(s.aggregate_fit(1, &[0.0], &results).is_err());
    }

    #[test]
    fn krum_output_is_one_of_the_inputs() {
        let mut s = Krum { f: 0 };
        let results = vec![
            fit(1, vec![1.0, 2.0], 1),
            fit(2, vec![3.0, 4.0], 1),
            fit(3, vec![1.2, 2.2], 1),
        ];
        let out = s.aggregate_fit(1, &[0.0, 0.0], &results).unwrap();
        assert!(results.iter().any(|r| r.parameters == out));
    }
}
