//! Byzantine-robust aggregation: coordinate-wise median and trimmed
//! mean (Yin et al., 2018) and Krum (Blanchard et al., 2017) — part of
//! Flower's strategy zoo that FLARE users gain access to through the
//! integration (paper §6 "direct utilization of FL algorithms ... from
//! Flower"). All three reduce per tensor over the record structure.

use super::{check_same_structure, FitAgg, FitRes, SortedBuffer, Strategy};
use crate::flower::records::{ArrayRecord, Tensor};

/// Coordinate-wise, per-tensor reduction helper: for every tensor in
/// the (validated, shared) record structure, `reduce` maps the sorted-
/// by-nothing column of client values at each coordinate to one value.
fn per_tensor_coordinate_reduce(
    results: &[FitRes],
    mut reduce: impl FnMut(&mut Vec<f64>) -> f64,
) -> ArrayRecord {
    let structure = &results[0].parameters;
    let mut tensors = Vec::with_capacity(structure.len());
    let mut col: Vec<f64> = Vec::with_capacity(results.len());
    for (ti, t) in structure.tensors().iter().enumerate() {
        let n = t.elems();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            col.clear();
            for r in results {
                col.push(r.parameters.tensors()[ti].get_f64(i));
            }
            out.push(reduce(&mut col));
        }
        tensors.push(Tensor::from_f64_values(
            t.name(),
            t.dtype(),
            t.shape().to_vec(),
            out.into_iter(),
        ));
    }
    ArrayRecord::from_tensors(tensors).expect("structure preserved")
}

/// Coordinate-wise median (unweighted — robustness over efficiency).
pub struct FedMedian;

impl Strategy for FedMedian {
    fn name(&self) -> &'static str {
        "fedmedian"
    }

    /// Explicit (the default is already `true`): coordinate-wise median
    /// is the canonical committee-filtered reduction — robust to any
    /// minority of surviving outliers.
    fn supports_byzantine(&self) -> bool {
        true
    }

    fn begin_fit(&mut self, _round: u64, _current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        Box::new(SortedBuffer::new(|results: &[FitRes]| {
            check_same_structure(results)?;
            Ok(per_tensor_coordinate_reduce(results, |col| {
                col.sort_by(f64::total_cmp);
                let k = col.len();
                if k % 2 == 1 {
                    col[k / 2]
                } else {
                    (col[k / 2 - 1] + col[k / 2]) / 2.0
                }
            }))
        }))
    }
}

/// Coordinate-wise trimmed mean: drop the `trim` largest and smallest
/// values per coordinate, average the rest.
pub struct TrimmedMean {
    pub trim: usize,
}

impl Strategy for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    /// Explicit (the default is already `true`): trimming tolerates a
    /// committee-filtered cohort as long as `n > 2*trim` survivors fold.
    fn supports_byzantine(&self) -> bool {
        true
    }

    fn begin_fit(&mut self, _round: u64, _current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        let trim = self.trim;
        Box::new(SortedBuffer::new(move |results: &[FitRes]| {
            anyhow::ensure!(
                results.len() > 2 * trim,
                "need more than {} clients to trim {} each side",
                2 * trim,
                trim
            );
            check_same_structure(results)?;
            Ok(per_tensor_coordinate_reduce(results, |col| {
                col.sort_by(f64::total_cmp);
                let kept = &col[trim..col.len() - trim];
                kept.iter().sum::<f64>() / kept.len() as f64
            }))
        }))
    }
}

/// Krum (Blanchard et al., 2017): pick the single client update whose
/// summed squared distance to its n-f-2 nearest neighbours is smallest
/// (tolerates up to `f` Byzantine clients). Distances sum over every
/// tensor in the record.
pub struct Krum {
    /// Assumed maximum number of Byzantine clients.
    pub f: usize,
}

impl Strategy for Krum {
    fn name(&self) -> &'static str {
        "krum"
    }

    /// Explicit (the default is already `true`): Krum assumes up to `f`
    /// Byzantine inputs by design; a committee-filtered cohort only
    /// lowers the effective `f` it has to absorb.
    fn supports_byzantine(&self) -> bool {
        true
    }

    fn begin_fit(&mut self, _round: u64, _current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        let f = self.f;
        Box::new(SortedBuffer::new(move |results: &[FitRes]| {
            krum_select(f, results)
        }))
    }
}

/// The Krum reduction over node-id-sorted results.
fn krum_select(f: usize, results: &[FitRes]) -> anyhow::Result<ArrayRecord> {
    let n = results.len();
    anyhow::ensure!(n > 2 * f + 2, "krum needs n > 2f+2 (n={n}, f={f})");
    let structure = check_same_structure(results)?;
    let n_tensors = structure.len();
    // Pairwise squared distances across all tensors.
    let mut d2 = vec![vec![0f64; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let mut dist = 0f64;
            for ti in 0..n_tensors {
                let a = &results[i].parameters.tensors()[ti];
                let b = &results[j].parameters.tensors()[ti];
                for e in 0..a.elems() {
                    let d = a.get_f64(e) - b.get_f64(e);
                    dist += d * d;
                }
            }
            d2[i][j] = dist;
            d2[j][i] = dist;
        }
    }
    // Score = sum of the n-f-2 smallest distances to others.
    let keep = n - f - 2;
    let mut best = (f64::INFINITY, 0usize);
    for i in 0..n {
        let mut ds: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| d2[i][j]).collect();
        ds.sort_by(f64::total_cmp);
        let score: f64 = ds.iter().take(keep).sum();
        if score < best.0 {
            best = (score, i);
        }
    }
    Ok(results[best.1].parameters.clone())
}

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    fn flat(v: &[f32]) -> ArrayRecord {
        ArrayRecord::from_flat(v)
    }

    #[test]
    fn median_ignores_outlier() {
        let mut s = FedMedian;
        let out = s
            .aggregate_fit(
                1,
                &flat(&[0.0]),
                &[
                    fit(1, vec![1.0], 1),
                    fit(2, vec![2.0], 1),
                    fit(3, vec![1000.0], 1), // byzantine
                ],
            )
            .unwrap();
        assert_eq!(out.to_flat(), vec![2.0]);
    }

    #[test]
    fn median_even_count_averages_middle() {
        let mut s = FedMedian;
        let out = s
            .aggregate_fit(
                1,
                &flat(&[0.0]),
                &[
                    fit(1, vec![1.0], 1),
                    fit(2, vec![2.0], 1),
                    fit(3, vec![3.0], 1),
                    fit(4, vec![4.0], 1),
                ],
            )
            .unwrap();
        assert_eq!(out.to_flat(), vec![2.5]);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let mut s = TrimmedMean { trim: 1 };
        let out = s
            .aggregate_fit(
                1,
                &flat(&[0.0]),
                &[
                    fit(1, vec![-100.0], 1),
                    fit(2, vec![1.0], 1),
                    fit(3, vec![3.0], 1),
                    fit(4, vec![100.0], 1),
                ],
            )
            .unwrap();
        assert_eq!(out.to_flat(), vec![2.0]);
    }

    #[test]
    fn trimmed_mean_needs_enough_clients() {
        let mut s = TrimmedMean { trim: 1 };
        assert!(s
            .aggregate_fit(
                1,
                &flat(&[0.0]),
                &[fit(1, vec![1.0], 1), fit(2, vec![2.0], 1)]
            )
            .is_err());
    }

    #[test]
    fn krum_picks_a_clustered_honest_update() {
        let mut s = Krum { f: 1 };
        // 4 honest updates near (1,1); 1 Byzantine at (100, -100).
        let results = vec![
            fit(1, vec![1.0, 1.0], 1),
            fit(2, vec![1.1, 0.9], 1),
            fit(3, vec![0.9, 1.1], 1),
            fit(4, vec![1.05, 1.0], 1),
            fit(5, vec![100.0, -100.0], 1),
        ];
        let out = s
            .aggregate_fit(1, &flat(&[0.0, 0.0]), &results)
            .unwrap()
            .to_flat();
        assert!(out[0] < 2.0 && out[1] > 0.0, "picked byzantine: {out:?}");
    }

    #[test]
    fn krum_requires_enough_clients() {
        let mut s = Krum { f: 1 };
        let results = vec![
            fit(1, vec![1.0], 1),
            fit(2, vec![1.0], 1),
            fit(3, vec![1.0], 1),
            fit(4, vec![1.0], 1),
        ];
        // n=4 is NOT > 2f+2=4.
        assert!(s.aggregate_fit(1, &flat(&[0.0]), &results).is_err());
    }

    #[test]
    fn krum_output_is_one_of_the_inputs() {
        let mut s = Krum { f: 0 };
        let results = vec![
            fit(1, vec![1.0, 2.0], 1),
            fit(2, vec![3.0, 4.0], 1),
            fit(3, vec![1.2, 2.2], 1),
        ];
        let out = s.aggregate_fit(1, &flat(&[0.0, 0.0]), &results).unwrap();
        assert!(results.iter().any(|r| r.parameters.bits_equal(&out)));
    }

    #[test]
    fn median_multi_tensor_reduces_each_tensor() {
        use crate::flower::records::Tensor;
        let mk = |a: f32, b: i64, id: u64| FitRes {
            node_id: id,
            parameters: ArrayRecord::from_tensors(vec![
                Tensor::from_f32("w", vec![1], &[a]),
                Tensor::from_i64("s", vec![1], &[b]),
            ])
            .unwrap(),
            num_examples: 1,
            metrics: crate::flower::records::MetricRecord::new(),
        };
        let mut s = FedMedian;
        let out = s
            .aggregate_fit(
                1,
                &mk(0.0, 0, 0).parameters,
                &[mk(1.0, 5, 1), mk(2.0, 6, 2), mk(99.0, 1000, 3)],
            )
            .unwrap();
        assert_eq!(out.get("w").unwrap().get_f64(0), 2.0);
        assert_eq!(out.get("s").unwrap().get_f64(0), 6.0);
    }
}
