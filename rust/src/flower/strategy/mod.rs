//! Server-side FL strategies (Flower's `Strategy` API; paper Listing 1
//! uses `FedAdam`). All aggregation is per-tensor over [`ArrayRecord`]s
//! and deterministic: results are canonicalized by node id before any
//! floating-point reduction, and every reduction iterates tensors in
//! record order — which is what makes the Fig. 5 native-vs-bridged
//! curves bit-identical.
//!
//! Aggregation is **incremental**: [`Strategy::begin_fit`] opens a
//! round's accumulator, results are [`FitAgg::accumulate`]d as they
//! arrive from the SuperLink (overlapping stragglers), and
//! [`FitAgg::finalize`] produces the next global record. The contract is
//! arrival-order independence: finalizing after any arrival order is
//! bit-identical to the batch reduction over the node-id-sorted set.
//! [`SortedBuffer`] gets this by canonicalizing before reducing;
//! accumulators whose arithmetic is exact and commutative (secure
//! aggregation's wrapping fixed-point sums) stream in O(1) memory.

mod fedavg;
mod fedopt;
mod fedprox;
mod robust;

pub use fedavg::{FedAvg, FedAvgM};
pub use fedopt::{FedAdagrad, FedAdam, FedOptConfig, FedYogi};
pub use fedprox::FedProx;
pub use robust::{FedMedian, Krum, TrimmedMean};

use crate::flower::message::{ConfigRecord, MetricRecord};
use crate::flower::records::{ArrayRecord, DType, Tensor};
use crate::runtime::{ComputeHandle, TensorData};

/// A fit result as seen by the strategy (already success-filtered and
/// sorted by node id).
#[derive(Clone, Debug, PartialEq)]
pub struct FitRes {
    pub node_id: u64,
    pub parameters: ArrayRecord,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalRes {
    pub node_id: u64,
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

/// An accumulator's mid-round state, exact to the bit: the results
/// absorbed so far, in arrival order. Buffering accumulators can
/// always produce one; streaming accumulators whose internal state is
/// not a result list (secure aggregation's masked sums) decline with
/// `None` and recovery falls back to the last round boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum AggSnapshot {
    Fit(Vec<FitRes>),
    Eval(Vec<EvalRes>),
}

/// One round's incremental fit aggregation, created by
/// [`Strategy::begin_fit`]. Accumulators absorb results in arrival
/// order; `finalize` must be bit-identical to the batch reduction over
/// the node-id-sorted result set regardless of that order (the Fig. 5
/// reproducibility invariant).
pub trait FitAgg {
    /// Absorb one successful fit result.
    fn accumulate(&mut self, res: FitRes) -> anyhow::Result<()>;

    /// Results absorbed so far.
    fn count(&self) -> usize;

    /// Reduce to the next global parameter record.
    fn finalize(self: Box<Self>) -> anyhow::Result<ArrayRecord>;

    /// Exact mid-round state for a driver checkpoint, or `None` for
    /// accumulators that decline snapshots (see [`AggSnapshot`]).
    fn snapshot(&self) -> Option<AggSnapshot> {
        None
    }

    /// Restore a fresh accumulator from a snapshot taken by the same
    /// strategy before a crash. Must leave the accumulator bit-
    /// identical to one that absorbed the snapshot's results live.
    fn restore(&mut self, _snap: AggSnapshot) -> anyhow::Result<()> {
        anyhow::bail!("accumulator does not support snapshot restore")
    }
}

/// Canonicalizing accumulator: buffers results (cheap — each is a
/// zero-copy view of its arrival frame), sorts by node id at finalize,
/// then applies a batch reduction. The default shape for strategies
/// whose floating-point reduction is order-sensitive; reductions that
/// are exact and commutative should stream instead (see
/// `secagg::SecAggFedAvg`).
pub struct SortedBuffer<F> {
    buf: Vec<FitRes>,
    reduce: F,
}

impl<F> SortedBuffer<F>
where
    F: FnOnce(&[FitRes]) -> anyhow::Result<ArrayRecord>,
{
    pub fn new(reduce: F) -> Self {
        Self {
            buf: Vec::new(),
            reduce,
        }
    }
}

impl<F> FitAgg for SortedBuffer<F>
where
    F: FnOnce(&[FitRes]) -> anyhow::Result<ArrayRecord>,
{
    fn accumulate(&mut self, res: FitRes) -> anyhow::Result<()> {
        self.buf.push(res);
        Ok(())
    }

    fn count(&self) -> usize {
        self.buf.len()
    }

    fn finalize(self: Box<Self>) -> anyhow::Result<ArrayRecord> {
        let mut this = *self;
        // Canonical reduction order, independent of arrival order.
        this.buf.sort_by_key(|r| r.node_id);
        (this.reduce)(&this.buf)
    }

    fn snapshot(&self) -> Option<AggSnapshot> {
        Some(AggSnapshot::Fit(self.buf.clone()))
    }

    fn restore(&mut self, snap: AggSnapshot) -> anyhow::Result<()> {
        match snap {
            AggSnapshot::Fit(buf) => {
                self.buf = buf;
                Ok(())
            }
            AggSnapshot::Eval(_) => anyhow::bail!("eval snapshot offered to a fit accumulator"),
        }
    }
}

pub trait Strategy: Send {
    fn name(&self) -> &'static str;

    /// Can a round finalize from a strict subset of the sampled cohort
    /// (partial participation under node churn)? True for every plain
    /// reduction; secure aggregation overrides to `false` — its pairwise
    /// masks only cancel when the FULL cohort contributes, so a dropout
    /// must fail the round instead of silently de-anonymizing sums.
    fn supports_partial(&self) -> bool {
        true
    }

    /// Can this strategy aggregate asynchronously (FedBuff-style: fold
    /// results cut from OLDER model versions into the current buffer)?
    /// True for every plain reduction; secure aggregation overrides to
    /// `false` — its masks are bound to a fixed round cohort, and a
    /// buffer mixing versions can never make them cancel.
    fn supports_async(&self) -> bool {
        true
    }

    /// Can this strategy's accumulators be snapshotted mid-round for a
    /// durability checkpoint, and its own state exported/imported
    /// across a crash? True for every plain reduction; secure
    /// aggregation overrides to `false` — persisting a partial masked
    /// sum would leak exactly the per-client updates the masks exist
    /// to hide, so its runs recover at round granularity only.
    fn supports_snapshot(&self) -> bool {
        true
    }

    /// Can this strategy's round be served by a sharded grid — its
    /// results folded into per-shard partial accumulators and merged at
    /// a root (see [`crate::flower::shard::ShardedGrid`])? True for
    /// every plain reduction, whose canonicalizing accumulators make
    /// the merge bit-identical to a flat link; secure aggregation
    /// overrides to `false` — its pairwise masks only cancel when one
    /// aggregator sees the FULL cohort, so a partial per-shard sum is
    /// both wrong and a privacy leak.
    fn supports_sharding(&self) -> bool {
        true
    }

    /// Can this strategy aggregate results that arrived under a LOSSY
    /// wire codec (fp16/bf16/int8/top-k — see
    /// [`crate::flower::records::WireCodec`])? True for every plain
    /// reduction, whose accumulators dequantize on fold; secure
    /// aggregation overrides to `false` — its pairwise masks are exact
    /// field elements that do not survive quantization, so a lossy
    /// codec would silently break mask cancellation. Lossless codecs
    /// (identity, delta) are always allowed.
    fn supports_lossy_codec(&self) -> bool {
        true
    }

    /// Can this strategy aggregate a cohort that committee validation
    /// has filtered (see [`crate::flower::committee`]) — i.e. tolerate
    /// some arrived results being excluded from the fold by a
    /// quarantine verdict? True for every plain reduction (the robust
    /// strategies exist precisely for this); secure aggregation
    /// overrides to `false` — its pairwise masks only cancel when
    /// EVERY arrived contribution folds, so dropping a quarantined
    /// update would corrupt the sum, and the plaintext inspection the
    /// committee needs contradicts masking anyway.
    fn supports_byzantine(&self) -> bool {
        true
    }

    /// Serialize cross-round optimizer state (momentum, adaptive
    /// moments) for a durability checkpoint. `None` means stateless —
    /// nothing beyond the global parameters needs to survive a crash.
    fn export_state(&self) -> Option<ArrayRecord> {
        None
    }

    /// Restore state exported by [`Strategy::export_state`] on an
    /// identically-configured strategy. The default accepts `None`
    /// exports trivially (stateless strategies ignore the call).
    fn import_state(&mut self, _state: &ArrayRecord) -> anyhow::Result<()> {
        Ok(())
    }

    /// Weight applied to a result whose model version lags the current
    /// global version by `delta` commits (0 = fresh). Must be exactly
    /// 1.0 at `delta == 0` so synchronous-equivalent async runs stay
    /// bit-identical. Default: the FedBuff polynomial
    /// `1 / sqrt(1 + delta)`.
    fn staleness_weight(&self, delta: u64) -> f64 {
        1.0 / (1.0 + delta as f64).sqrt()
    }

    /// Extra config pushed to clients with each fit instruction.
    fn configure_fit(&mut self, _round: u64) -> ConfigRecord {
        ConfigRecord::new()
    }

    fn configure_evaluate(&mut self, _round: u64) -> ConfigRecord {
        ConfigRecord::new()
    }

    /// Begin incremental aggregation for `round`. `current` is the
    /// record the round started from.
    fn begin_fit(&mut self, round: u64, current: &ArrayRecord) -> Box<dyn FitAgg + '_>;

    /// Batch convenience: stream `results` (any order) through a fresh
    /// accumulator. Bit-identical to driving [`Strategy::begin_fit`] by
    /// hand — for tests and call sites that already hold the full set.
    fn aggregate_fit(
        &mut self,
        round: u64,
        current: &ArrayRecord,
        results: &[FitRes],
    ) -> anyhow::Result<ArrayRecord> {
        let mut agg = self.begin_fit(round, current);
        for r in results {
            agg.accumulate(r.clone())?;
        }
        agg.finalize()
    }

    /// Weighted-average loss/metrics (Flower's default behaviour).
    fn aggregate_evaluate(&mut self, _round: u64, results: &[EvalRes]) -> (f64, MetricRecord) {
        weighted_eval(results)
    }

    /// Begin incremental EVALUATION aggregation for `round`: results
    /// stream into a small accumulator as they arrive (an [`EvalRes`] is
    /// a handful of floats — the driver no longer buffers the cohort's
    /// full `TaskRes` frames through a quorum wait). The default
    /// canonicalizes by node id at finalize and applies
    /// [`Strategy::aggregate_evaluate`], so streaming is bit-identical
    /// to the batch path in any arrival order.
    fn begin_evaluate(&mut self, round: u64) -> Box<dyn EvalAgg + '_> {
        Box::new(SortedEvalBuffer::new(move |results: &[EvalRes]| {
            self.aggregate_evaluate(round, results)
        }))
    }
}

/// One round's incremental evaluate aggregation, created by
/// [`Strategy::begin_evaluate`]. Mirrors [`FitAgg`] for the (much
/// lighter) evaluation phase.
pub trait EvalAgg {
    /// Absorb one successful evaluation result.
    fn accumulate(&mut self, res: EvalRes);

    /// Results absorbed so far.
    fn count(&self) -> usize;

    /// Reduce to the aggregated (loss, metrics).
    fn finalize(self: Box<Self>) -> (f64, MetricRecord);

    /// Exact mid-round state for a driver checkpoint (see
    /// [`FitAgg::snapshot`]).
    fn snapshot(&self) -> Option<AggSnapshot> {
        None
    }

    /// Restore a fresh accumulator from a snapshot (see
    /// [`FitAgg::restore`]).
    fn restore(&mut self, _snap: AggSnapshot) -> anyhow::Result<()> {
        anyhow::bail!("accumulator does not support snapshot restore")
    }
}

/// Canonicalizing evaluate accumulator: buffers the (small) `EvalRes`
/// structs, sorts by node id at finalize, then applies a batch
/// reduction — the [`SortedBuffer`] pattern for the eval phase.
pub struct SortedEvalBuffer<F> {
    buf: Vec<EvalRes>,
    reduce: F,
}

impl<F> SortedEvalBuffer<F>
where
    F: FnOnce(&[EvalRes]) -> (f64, MetricRecord),
{
    pub fn new(reduce: F) -> Self {
        Self {
            buf: Vec::new(),
            reduce,
        }
    }
}

impl<F> EvalAgg for SortedEvalBuffer<F>
where
    F: FnOnce(&[EvalRes]) -> (f64, MetricRecord),
{
    fn accumulate(&mut self, res: EvalRes) {
        self.buf.push(res);
    }

    fn count(&self) -> usize {
        self.buf.len()
    }

    fn finalize(self: Box<Self>) -> (f64, MetricRecord) {
        let mut this = *self;
        // Canonical reduction order, independent of arrival order.
        this.buf.sort_by_key(|r| r.node_id);
        (this.reduce)(&this.buf)
    }

    fn snapshot(&self) -> Option<AggSnapshot> {
        Some(AggSnapshot::Eval(self.buf.clone()))
    }

    fn restore(&mut self, snap: AggSnapshot) -> anyhow::Result<()> {
        match snap {
            AggSnapshot::Eval(buf) => {
                self.buf = buf;
                Ok(())
            }
            AggSnapshot::Fit(_) => anyhow::bail!("fit snapshot offered to an eval accumulator"),
        }
    }
}

/// Weighted mean of losses + each metric key, weights = num_examples.
pub fn weighted_eval(results: &[EvalRes]) -> (f64, MetricRecord) {
    let total: f64 = results.iter().map(|r| r.num_examples as f64).sum();
    if total == 0.0 {
        return (0.0, MetricRecord::new());
    }
    let loss = results
        .iter()
        .map(|r| r.loss * r.num_examples as f64)
        .sum::<f64>()
        / total;
    let mut keys: Vec<&String> = results
        .iter()
        .flat_map(|r| r.metrics.iter().map(|(k, _)| k))
        .collect();
    keys.sort();
    keys.dedup();
    let metrics = keys
        .into_iter()
        .map(|k| {
            let v = results
                .iter()
                .filter_map(|r| {
                    r.metrics
                        .iter()
                        .find(|(mk, _)| mk == k)
                        .map(|(_, mv)| mv * r.num_examples as f64)
                })
                .sum::<f64>()
                / total;
            (k.clone(), v)
        })
        .collect();
    (loss, metrics)
}

/// Validate that every result carries the same record structure; returns
/// the reference structure (the first result's).
pub fn check_same_structure(results: &[FitRes]) -> anyhow::Result<&ArrayRecord> {
    anyhow::ensure!(!results.is_empty(), "no fit results to aggregate");
    let first = &results[0].parameters;
    for r in &results[1..] {
        anyhow::ensure!(
            r.parameters.dims_match(first),
            "record structure mismatch: node {} differs from node {}",
            r.node_id,
            results[0].node_id
        );
    }
    Ok(first)
}

/// Example-weighted parameter mean — the FedAvg reduction, per tensor.
/// Runs on the L1 Pallas `fedavg_<model>_k<K>` artifact via PJRT when
/// one matches the (model, K, N) shape and the record is all-f32;
/// otherwise falls back to the (identically associated) Rust loop. Both
/// paths reduce client-major, so results are bit-comparable across runs
/// of the same path.
#[derive(Clone, Default)]
pub struct Aggregator {
    compute: Option<(ComputeHandle, String)>,
}

impl Aggregator {
    /// Pure-Rust aggregator.
    pub fn host() -> Self {
        Self { compute: None }
    }

    /// PJRT-backed aggregator for `model` (falls back per-call when no
    /// artifact matches the client count).
    pub fn pjrt(handle: ComputeHandle, model: &str) -> Self {
        Self {
            compute: Some((handle, model.to_string())),
        }
    }

    pub fn weighted_mean(&self, results: &[FitRes]) -> anyhow::Result<ArrayRecord> {
        let structure = check_same_structure(results)?;
        // The device path stacks flat f32 payloads, so it additionally
        // requires every result to be dense (identity-encoded) —
        // compressed results fall back to the host fold, which
        // dequantizes on accumulate.
        let all_f32 = structure.tensors().iter().all(|t| t.dtype() == DType::F32)
            && results.iter().all(|r| r.parameters.is_all_dense());
        if all_f32 {
            if let Some((handle, model)) = &self.compute {
                let n = structure.total_elems();
                let artifact = format!("fedavg_{}_k{}", model, results.len());
                if handle.has_artifact(&artifact) {
                    let meta = handle.manifest().artifact(&artifact).unwrap();
                    if meta.inputs[0].shape == vec![results.len(), n] {
                        let mut stacked = Vec::with_capacity(results.len() * n);
                        for r in results {
                            stacked.extend_from_slice(&r.parameters.to_flat());
                        }
                        let weights: Vec<f32> =
                            results.iter().map(|r| r.num_examples as f32).collect();
                        let out = handle.execute(
                            &artifact,
                            vec![
                                TensorData::F32(stacked, vec![results.len(), n]),
                                TensorData::F32(weights, vec![results.len()]),
                            ],
                        )?;
                        crate::telemetry::bump("strategy.pjrt_aggregations", 1);
                        let flat = match out.into_iter().next() {
                            Some(TensorData::F32(v, _)) => v,
                            other => anyhow::bail!("unexpected fedavg output {other:?}"),
                        };
                        // Re-wrap in the record's (layer-named) structure.
                        return structure.from_flat_like(&flat);
                    }
                }
            }
        }
        crate::telemetry::bump("strategy.host_aggregations", 1);
        Ok(host_weighted_mean(results))
    }
}

/// Reference Rust reduction (shared by tests): per-tensor example-
/// weighted mean in f64, cast back to each tensor's dtype.
///
/// Panics if `results` is empty or structures mismatch — call
/// [`check_same_structure`] first on untrusted input.
pub fn host_weighted_mean(results: &[FitRes]) -> ArrayRecord {
    let total: f64 = results.iter().map(|r| r.num_examples as f64).sum();
    let structure = &results[0].parameters;
    let mut tensors = Vec::with_capacity(structure.len());
    for (ti, t) in structure.tensors().iter().enumerate() {
        let n = t.elems();
        let mut acc = vec![0f64; n];
        for r in results {
            let rt = &r.parameters.tensors()[ti];
            assert_eq!(rt.elems(), n, "tensor '{}' length mismatch", t.name());
            let w = r.num_examples as f64 / total;
            // One pass per wire encoding: dense f32 keeps the linear
            // scan over the packed payload, quantized segments (fp16/
            // bf16/int8) dequantize AS they fold — never through an
            // intermediate dense copy — and top-k touches only its
            // kept entries.
            rt.fold_weighted(&mut acc, w);
        }
        tensors.push(Tensor::from_f64_values(
            t.name(),
            t.dtype(),
            t.shape().to_vec(),
            acc.into_iter(),
        ));
    }
    ArrayRecord::from_tensors(tensors).expect("structure preserved")
}

#[cfg(test)]
pub(crate) fn fit(node_id: u64, parameters: Vec<f32>, num_examples: u64) -> FitRes {
    FitRes {
        node_id,
        parameters: ArrayRecord::from_flat(&parameters),
        num_examples,
        metrics: MetricRecord::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::records::WireCodec;

    #[test]
    fn host_weighted_mean_math() {
        let results = vec![fit(1, vec![0.0, 2.0], 1), fit(2, vec![4.0, 6.0], 3)];
        let out = host_weighted_mean(&results);
        assert_eq!(out.to_flat(), vec![3.0, 5.0]);
    }

    #[test]
    fn host_weighted_mean_folds_compressed_results_in_one_pass() {
        // The same cohort, once dense and once wire-compressed; lossless
        // sparsification of sparse updates is bit-identical, lossy
        // quantization lands within its stated tolerance.
        let a: Vec<f32> = vec![0.5, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0, 0.0];
        let b: Vec<f32> = vec![0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.25, 0.0];
        let dense = host_weighted_mean(&[fit(1, a.clone(), 1), fit(2, b.clone(), 3)]);

        let compress = |vals: &[f32], codec| FitRes {
            parameters: ArrayRecord::from_flat(vals).compress(codec, None),
            ..fit(0, vec![], 0)
        };
        // top-k keeps ceil(8/4) = 2 entries: exactly each node's support.
        let topk = host_weighted_mean(&[
            FitRes {
                node_id: 1,
                num_examples: 1,
                ..compress(&a, WireCodec::TopK)
            },
            FitRes {
                node_id: 2,
                num_examples: 3,
                ..compress(&b, WireCodec::TopK)
            },
        ]);
        assert!(dense.bits_equal(&topk), "sparse top-k is lossless here");

        for (codec, tol) in [
            (WireCodec::F16, 1e-3),
            (WireCodec::Bf16, 2e-2),
            (WireCodec::Int8, 2e-2),
        ] {
            let lossy = host_weighted_mean(&[
                FitRes {
                    node_id: 1,
                    num_examples: 1,
                    ..compress(&a, codec)
                },
                FitRes {
                    node_id: 2,
                    num_examples: 3,
                    ..compress(&b, codec)
                },
            ]);
            for (d, l) in dense.to_flat().iter().zip(lossy.to_flat()) {
                assert!(
                    (d - l).abs() <= tol,
                    "{codec:?}: {d} vs {l} exceeds tolerance {tol}"
                );
            }
        }
    }

    #[test]
    fn device_path_falls_back_to_host_for_compressed_results() {
        // A mixed cohort (one dense, one quantized) must not take the
        // flat-stacking device path; the host fold handles it.
        let results = vec![
            fit(1, vec![0.0, 2.0], 1),
            FitRes {
                node_id: 2,
                num_examples: 3,
                parameters: ArrayRecord::from_flat(&[4.0, 6.0]).compress(WireCodec::F16, None),
                metrics: MetricRecord::new(),
            },
        ];
        let out = Aggregator::host().weighted_mean(&results).unwrap();
        assert_eq!(out.to_flat(), vec![3.0, 5.0], "f16 holds 4.0/6.0 exactly");
    }

    #[test]
    fn host_weighted_mean_per_tensor_mixed_dtype() {
        let mk = |w: &[f32], steps: &[i64], n: u64, id: u64| FitRes {
            node_id: id,
            parameters: ArrayRecord::from_tensors(vec![
                Tensor::from_f32("w", vec![2], w),
                Tensor::from_i64("steps", vec![1], steps),
            ])
            .unwrap(),
            num_examples: n,
            metrics: MetricRecord::new(),
        };
        let results = vec![mk(&[0.0, 2.0], &[10], 1, 1), mk(&[4.0, 6.0], &[20], 3, 2)];
        let out = Aggregator::host().weighted_mean(&results).unwrap();
        assert_eq!(out.get("w").unwrap().get_f64(0), 3.0);
        assert_eq!(out.get("w").unwrap().get_f64(1), 5.0);
        // I64 mean rounds: (10*0.25 + 20*0.75) = 17.5 -> 18.
        assert_eq!(out.get("steps").unwrap().dtype(), DType::I64);
        assert_eq!(out.get("steps").unwrap().get_f64(0), 18.0);
    }

    #[test]
    fn aggregator_host_fallback() {
        let agg = Aggregator::host();
        let out = agg
            .weighted_mean(&[fit(1, vec![1.0], 1), fit(2, vec![3.0], 1)])
            .unwrap();
        assert_eq!(out.to_flat(), vec![2.0]);
    }

    #[test]
    fn aggregator_rejects_mismatched_structures() {
        let agg = Aggregator::host();
        assert!(agg
            .weighted_mean(&[fit(1, vec![1.0], 1), fit(2, vec![1.0, 2.0], 1)])
            .is_err());
        assert!(agg.weighted_mean(&[]).is_err());
    }

    #[test]
    fn staleness_weight_default_is_polynomial_and_unit_at_zero() {
        let s = FedAvg::new(Aggregator::host());
        assert_eq!(s.staleness_weight(0), 1.0, "delta 0 must weigh exactly 1");
        assert!((s.staleness_weight(3) - 0.5).abs() < 1e-12, "1/sqrt(4)");
        let mut prev = 1.0;
        for d in 1..10 {
            let w = s.staleness_weight(d);
            assert!(w < prev && w > 0.0, "monotone decreasing, positive");
            prev = w;
        }
        assert!(s.supports_async(), "plain reductions support async");
    }

    #[test]
    fn begin_evaluate_streams_bit_identical_to_batch() {
        let results = vec![
            EvalRes {
                node_id: 2,
                loss: 2.0,
                num_examples: 3,
                metrics: vec![("accuracy".to_string(), 1.0)].into(),
            },
            EvalRes {
                node_id: 1,
                loss: 1.0,
                num_examples: 1,
                metrics: vec![("accuracy".to_string(), 0.0)].into(),
            },
        ];
        let mut sorted = results.clone();
        sorted.sort_by_key(|r| r.node_id);
        let mut s = FedAvg::new(Aggregator::host());
        let want = s.aggregate_evaluate(1, &sorted);
        // Stream in reverse-of-canonical order: finalize canonicalizes.
        let mut agg = s.begin_evaluate(1);
        for r in results {
            agg.accumulate(r);
        }
        assert_eq!(agg.count(), 2);
        let got = agg.finalize();
        assert_eq!(got.0.to_bits(), want.0.to_bits());
        assert_eq!(got.1.len(), want.1.len());
        for ((ka, va), (kb, vb)) in got.1.iter().zip(want.1.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    #[test]
    fn weighted_eval_math() {
        let results = vec![
            EvalRes {
                node_id: 1,
                loss: 1.0,
                num_examples: 1,
                metrics: vec![("accuracy".to_string(), 0.0)].into(),
            },
            EvalRes {
                node_id: 2,
                loss: 2.0,
                num_examples: 3,
                metrics: vec![("accuracy".to_string(), 1.0)].into(),
            },
        ];
        let (loss, metrics) = weighted_eval(&results);
        assert!((loss - 1.75).abs() < 1e-12);
        assert!((metrics[0].1 - 0.75).abs() < 1e-12);
    }
}
