//! Server-side FL strategies (Flower's `Strategy` API; paper Listing 1
//! uses `FedAdam`). All aggregation is deterministic: results are
//! canonicalized by node id before any floating-point reduction, which
//! is what makes the Fig. 5 native-vs-bridged curves bit-identical.

mod fedavg;
mod fedopt;
mod fedprox;
mod robust;

pub use fedavg::{FedAvg, FedAvgM};
pub use fedopt::{FedAdagrad, FedAdam, FedOptConfig, FedYogi};
pub use fedprox::FedProx;
pub use robust::{FedMedian, Krum, TrimmedMean};

use crate::flower::message::{ConfigRecord, MetricRecord};
use crate::runtime::{ComputeHandle, TensorData};

/// A fit result as seen by the strategy (already success-filtered and
/// sorted by node id).
#[derive(Clone, Debug)]
pub struct FitRes {
    pub node_id: u64,
    pub parameters: Vec<f32>,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

#[derive(Clone, Debug)]
pub struct EvalRes {
    pub node_id: u64,
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: MetricRecord,
}

pub trait Strategy: Send {
    fn name(&self) -> &'static str;

    /// Extra config pushed to clients with each fit instruction.
    fn configure_fit(&mut self, _round: u64) -> ConfigRecord {
        Vec::new()
    }

    fn configure_evaluate(&mut self, _round: u64) -> ConfigRecord {
        Vec::new()
    }

    /// Combine client updates into the next global parameter vector.
    /// `current` is the global vector the round started from.
    fn aggregate_fit(
        &mut self,
        round: u64,
        current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>>;

    /// Weighted-average loss/metrics (Flower's default behaviour).
    fn aggregate_evaluate(&mut self, _round: u64, results: &[EvalRes]) -> (f64, MetricRecord) {
        weighted_eval(results)
    }
}

/// Weighted mean of losses + each metric key, weights = num_examples.
pub fn weighted_eval(results: &[EvalRes]) -> (f64, MetricRecord) {
    let total: f64 = results.iter().map(|r| r.num_examples as f64).sum();
    if total == 0.0 {
        return (0.0, Vec::new());
    }
    let loss = results
        .iter()
        .map(|r| r.loss * r.num_examples as f64)
        .sum::<f64>()
        / total;
    let mut keys: Vec<&String> = results
        .iter()
        .flat_map(|r| r.metrics.iter().map(|(k, _)| k))
        .collect();
    keys.sort();
    keys.dedup();
    let metrics = keys
        .into_iter()
        .map(|k| {
            let v = results
                .iter()
                .filter_map(|r| {
                    r.metrics
                        .iter()
                        .find(|(mk, _)| mk == k)
                        .map(|(_, mv)| mv * r.num_examples as f64)
                })
                .sum::<f64>()
                / total;
            (k.clone(), v)
        })
        .collect();
    (loss, metrics)
}

/// Example-weighted parameter mean — the FedAvg reduction. Runs on the
/// L1 Pallas `fedavg_<model>_k<K>` artifact via PJRT when one matches
/// the (model, K, N) shape; otherwise falls back to the (identically
/// associated) Rust loop. Both paths reduce client-major, so results are
/// bit-comparable across runs of the same path.
#[derive(Clone, Default)]
pub struct Aggregator {
    compute: Option<(ComputeHandle, String)>,
}

impl Aggregator {
    /// Pure-Rust aggregator.
    pub fn host() -> Self {
        Self { compute: None }
    }

    /// PJRT-backed aggregator for `model` (falls back per-call when no
    /// artifact matches the client count).
    pub fn pjrt(handle: ComputeHandle, model: &str) -> Self {
        Self {
            compute: Some((handle, model.to_string())),
        }
    }

    pub fn weighted_mean(&self, results: &[FitRes]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!results.is_empty(), "no fit results to aggregate");
        let n = results[0].parameters.len();
        for r in results {
            anyhow::ensure!(
                r.parameters.len() == n,
                "parameter length mismatch: {} vs {n}",
                r.parameters.len()
            );
        }
        if let Some((handle, model)) = &self.compute {
            let artifact = format!("fedavg_{}_k{}", model, results.len());
            if handle.has_artifact(&artifact) {
                let meta = handle.manifest().artifact(&artifact).unwrap();
                if meta.inputs[0].shape == vec![results.len(), n] {
                    let mut stacked = Vec::with_capacity(results.len() * n);
                    for r in results {
                        stacked.extend_from_slice(&r.parameters);
                    }
                    let weights: Vec<f32> =
                        results.iter().map(|r| r.num_examples as f32).collect();
                    let out = handle.execute(
                        &artifact,
                        vec![
                            TensorData::F32(stacked, vec![results.len(), n]),
                            TensorData::F32(weights, vec![results.len()]),
                        ],
                    )?;
                    crate::telemetry::bump("strategy.pjrt_aggregations", 1);
                    return Ok(match out.into_iter().next() {
                        Some(TensorData::F32(v, _)) => v,
                        other => anyhow::bail!("unexpected fedavg output {other:?}"),
                    });
                }
            }
        }
        crate::telemetry::bump("strategy.host_aggregations", 1);
        Ok(host_weighted_mean(results))
    }
}

/// Reference Rust reduction (shared by tests).
pub fn host_weighted_mean(results: &[FitRes]) -> Vec<f32> {
    let n = results[0].parameters.len();
    let total: f64 = results.iter().map(|r| r.num_examples as f64).sum();
    let mut out = vec![0f64; n];
    for r in results {
        let w = r.num_examples as f64 / total;
        for (o, p) in out.iter_mut().zip(r.parameters.iter()) {
            *o += w * *p as f64;
        }
    }
    out.into_iter().map(|x| x as f32).collect()
}

#[cfg(test)]
pub(crate) fn fit(node_id: u64, parameters: Vec<f32>, num_examples: u64) -> FitRes {
    FitRes {
        node_id,
        parameters,
        num_examples,
        metrics: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_weighted_mean_math() {
        let results = vec![fit(1, vec![0.0, 2.0], 1), fit(2, vec![4.0, 6.0], 3)];
        let out = host_weighted_mean(&results);
        assert_eq!(out, vec![3.0, 5.0]);
    }

    #[test]
    fn aggregator_host_fallback() {
        let agg = Aggregator::host();
        let out = agg
            .weighted_mean(&[fit(1, vec![1.0], 1), fit(2, vec![3.0], 1)])
            .unwrap();
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn aggregator_rejects_mismatched_lengths() {
        let agg = Aggregator::host();
        assert!(agg
            .weighted_mean(&[fit(1, vec![1.0], 1), fit(2, vec![1.0, 2.0], 1)])
            .is_err());
        assert!(agg.weighted_mean(&[]).is_err());
    }

    #[test]
    fn weighted_eval_math() {
        let results = vec![
            EvalRes {
                node_id: 1,
                loss: 1.0,
                num_examples: 1,
                metrics: vec![("accuracy".into(), 0.0)],
            },
            EvalRes {
                node_id: 2,
                loss: 2.0,
                num_examples: 3,
                metrics: vec![("accuracy".into(), 1.0)],
            },
        ];
        let (loss, metrics) = weighted_eval(&results);
        assert!((loss - 1.75).abs() < 1e-12);
        assert!((metrics[0].1 - 0.75).abs() < 1e-12);
    }
}
