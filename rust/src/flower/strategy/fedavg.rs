//! FedAvg (McMahan et al., 2017) and FedAvgM (server momentum), over
//! per-tensor records.

use std::collections::HashMap;

use super::{Aggregator, FitAgg, FitRes, SortedBuffer, Strategy};
use crate::flower::records::{ArrayRecord, DType, Tensor};

/// Plain federated averaging: example-weighted mean of client updates.
pub struct FedAvg {
    agg: Aggregator,
}

impl FedAvg {
    pub fn new(agg: Aggregator) -> Self {
        Self { agg }
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn begin_fit(&mut self, _round: u64, _current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        let agg = self.agg.clone();
        Box::new(SortedBuffer::new(move |results: &[FitRes]| {
            agg.weighted_mean(results)
        }))
    }
}

/// FedAvg with server momentum (Hsu et al., 2019): the server applies a
/// momentum-accelerated pseudo-gradient instead of jumping to the mean.
/// Velocity state is kept per tensor name, so per-layer records carry
/// independent momenta.
pub struct FedAvgM {
    agg: Aggregator,
    momentum: f64,
    server_lr: f64,
    velocity: HashMap<String, Vec<f64>>,
}

impl FedAvgM {
    pub fn new(agg: Aggregator, momentum: f64, server_lr: f64) -> Self {
        Self {
            agg,
            momentum,
            server_lr,
            velocity: HashMap::new(),
        }
    }

    fn step(&mut self, current: &ArrayRecord, results: &[FitRes]) -> anyhow::Result<ArrayRecord> {
        let mean = self.agg.weighted_mean(results)?;
        anyhow::ensure!(
            mean.dims_match(current),
            "aggregated record structure differs from current"
        );
        let mut tensors = Vec::with_capacity(current.len());
        for (cur, avg) in current.tensors().iter().zip(mean.tensors().iter()) {
            let n = cur.elems();
            let v = self.velocity.entry(cur.name().to_string()).or_default();
            if v.len() != n {
                *v = vec![0.0; n];
            }
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                // Pseudo-gradient: current - mean (descent direction).
                let g = cur.get_f64(i) - avg.get_f64(i);
                v[i] = self.momentum * v[i] + g;
                out.push(cur.get_f64(i) - self.server_lr * v[i]);
            }
            tensors.push(Tensor::from_f64_values(
                cur.name(),
                cur.dtype(),
                cur.shape().to_vec(),
                out.into_iter(),
            ));
        }
        Ok(ArrayRecord::from_tensors(tensors)?)
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn begin_fit(&mut self, _round: u64, current: &ArrayRecord) -> Box<dyn FitAgg + '_> {
        let current = current.clone();
        Box::new(SortedBuffer::new(move |results: &[FitRes]| {
            self.step(&current, results)
        }))
    }

    /// Velocity per tensor name, as F64 tensors in sorted-name order
    /// (f64 payloads, so export -> import is bit-exact).
    fn export_state(&self) -> Option<ArrayRecord> {
        let mut names: Vec<&String> = self.velocity.keys().collect();
        names.sort();
        let tensors = names
            .into_iter()
            .map(|name| {
                let v = &self.velocity[name];
                Tensor::from_f64_values(name, DType::F64, vec![v.len()], v.iter().copied())
            })
            .collect();
        ArrayRecord::from_tensors(tensors).ok()
    }

    fn import_state(&mut self, state: &ArrayRecord) -> anyhow::Result<()> {
        self.velocity.clear();
        for t in state.tensors() {
            let vals = (0..t.elems()).map(|i| t.get_f64(i)).collect();
            self.velocity.insert(t.name().to_string(), vals);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    #[test]
    fn fedavg_is_weighted_mean() {
        let mut s = FedAvg::new(Aggregator::host());
        let out = s
            .aggregate_fit(
                1,
                &ArrayRecord::from_flat(&[0.0, 0.0]),
                &[fit(1, vec![0.0, 2.0], 1), fit(2, vec![4.0, 6.0], 3)],
            )
            .unwrap();
        assert_eq!(out.to_flat(), vec![3.0, 5.0]);
    }

    #[test]
    fn fedavg_streams_incrementally() {
        let mut s = FedAvg::new(Aggregator::host());
        let mut agg = s.begin_fit(1, &ArrayRecord::from_flat(&[0.0, 0.0]));
        // Reverse arrival order: finalize canonicalizes by node id.
        agg.accumulate(fit(2, vec![4.0, 6.0], 3)).unwrap();
        agg.accumulate(fit(1, vec![0.0, 2.0], 1)).unwrap();
        assert_eq!(agg.count(), 2);
        let out = agg.finalize().unwrap();
        assert_eq!(out.to_flat(), vec![3.0, 5.0]);
    }

    #[test]
    fn fedavgm_zero_momentum_unit_lr_equals_fedavg() {
        let mut m = FedAvgM::new(Aggregator::host(), 0.0, 1.0);
        let results = [fit(1, vec![1.0], 1), fit(2, vec![3.0], 1)];
        let out = m
            .aggregate_fit(1, &ArrayRecord::from_flat(&[0.0]), &results)
            .unwrap();
        assert!((out.to_flat()[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn fedavgm_momentum_accumulates() {
        let mut m = FedAvgM::new(Aggregator::host(), 0.9, 1.0);
        // Clients keep reporting the same point; velocity should build
        // toward it and overshoot without damping.
        let mut x = ArrayRecord::from_flat(&[0.0f32]);
        for round in 1..=3 {
            let results = [fit(1, vec![1.0], 1)];
            x = m.aggregate_fit(round, &x, &results).unwrap();
        }
        // Round 1: g=-1, v=-1,    x=1.
        // Round 2: g=0,  v=-0.9,  x=1.9.
        // Round 3: g=0.9, v=0.09, x=1.81 (overshoot, then pull back).
        let flat = x.to_flat();
        assert!((flat[0] - 1.81).abs() < 1e-4, "{flat:?}");
    }
}
