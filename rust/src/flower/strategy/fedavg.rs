//! FedAvg (McMahan et al., 2017) and FedAvgM (server momentum).

use super::{Aggregator, FitRes, Strategy};

/// Plain federated averaging: example-weighted mean of client updates.
pub struct FedAvg {
    agg: Aggregator,
}

impl FedAvg {
    pub fn new(agg: Aggregator) -> Self {
        Self { agg }
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        _current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>> {
        self.agg.weighted_mean(results)
    }
}

/// FedAvg with server momentum (Hsu et al., 2019): the server applies a
/// momentum-accelerated pseudo-gradient instead of jumping to the mean.
pub struct FedAvgM {
    agg: Aggregator,
    momentum: f64,
    server_lr: f64,
    velocity: Vec<f64>,
}

impl FedAvgM {
    pub fn new(agg: Aggregator, momentum: f64, server_lr: f64) -> Self {
        Self {
            agg,
            momentum,
            server_lr,
            velocity: Vec::new(),
        }
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        current: &[f32],
        results: &[FitRes],
    ) -> anyhow::Result<Vec<f32>> {
        let mean = self.agg.weighted_mean(results)?;
        if self.velocity.len() != current.len() {
            self.velocity = vec![0.0; current.len()];
        }
        let mut out = Vec::with_capacity(current.len());
        for i in 0..current.len() {
            // Pseudo-gradient: current - mean (descent direction).
            let g = current[i] as f64 - mean[i] as f64;
            self.velocity[i] = self.momentum * self.velocity[i] + g;
            out.push((current[i] as f64 - self.server_lr * self.velocity[i]) as f32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::fit;
    use super::*;

    #[test]
    fn fedavg_is_weighted_mean() {
        let mut s = FedAvg::new(Aggregator::host());
        let out = s
            .aggregate_fit(
                1,
                &[0.0, 0.0],
                &[fit(1, vec![0.0, 2.0], 1), fit(2, vec![4.0, 6.0], 3)],
            )
            .unwrap();
        assert_eq!(out, vec![3.0, 5.0]);
    }

    #[test]
    fn fedavgm_zero_momentum_unit_lr_equals_fedavg() {
        let mut m = FedAvgM::new(Aggregator::host(), 0.0, 1.0);
        let results = [fit(1, vec![1.0], 1), fit(2, vec![3.0], 1)];
        let out = m.aggregate_fit(1, &[0.0], &results).unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn fedavgm_momentum_accumulates() {
        let mut m = FedAvgM::new(Aggregator::host(), 0.9, 1.0);
        // Clients keep reporting the same point; velocity should build
        // toward it and overshoot without damping.
        let mut x = vec![0.0f32];
        for round in 1..=3 {
            let results = [fit(1, vec![1.0], 1)];
            x = m.aggregate_fit(round, &x, &results).unwrap();
        }
        // Round 1: g=-1, v=-1,    x=1.
        // Round 2: g=0,  v=-0.9,  x=1.9.
        // Round 3: g=0.9, v=0.09, x=1.81 (overshoot, then pull back).
        assert!((x[0] - 1.81).abs() < 1e-4, "{x:?}");
    }
}
