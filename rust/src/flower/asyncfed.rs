//! Asynchronous federation: FedBuff-style buffered, staleness-aware
//! aggregation (Nguyen et al., 2022) on top of the multi-run SuperLink.
//!
//! The synchronous driver barriers every round on its whole cohort, so
//! the fleet idles behind the slowest survivor. The async driver never
//! barriers: it keeps every node busy with a fit task tagged with the
//! global model **version** the task's parameters were cut from, folds
//! results into the strategy's incremental [`FitAgg`] accumulator as
//! they arrive, and **commits** a new global model every
//! [`AsyncConfig::buffer_size`] folded results. A result that lags the
//! current version by `delta` commits is weighted by
//! [`Strategy::staleness_weight`]`(delta)` (polynomial
//! `1/sqrt(1+delta)` by default, applied by scaling the result's
//! example count) and **dropped** outright past
//! [`AsyncConfig::max_staleness`].
//!
//! Dispatch discipline: each node executes at most ONE task per model
//! version (a deterministic client re-fitting the same version would
//! duplicate work and, with `buffer_size == cohort`, break the
//! sync-equivalence below). After every commit the version bumps and
//! the whole fleet becomes eligible again, so with `buffer_size <
//! cohort` nodes are effectively always busy.
//!
//! **Sync equivalence** (the conformance anchor): with
//! `buffer_size == cohort size` and `max_staleness == 0`, every commit
//! folds exactly one fresh result per node at weight exactly 1.0 into
//! the same canonicalizing accumulator the sync round path uses — the
//! final parameters are bit-identical to the synchronous driver's.
//!
//! Gating: [`Strategy::supports_async`] must hold.
//! `SecAggFedAvg` refuses (its pairwise masks are bound to one
//! (round, cohort) pair and can never cancel across versions),
//! mirroring `supports_partial`.
//!
//! [`Strategy::staleness_weight`]: crate::flower::strategy::Strategy::staleness_weight
//! [`Strategy::supports_async`]: crate::flower::strategy::Strategy::supports_async
//! [`FitAgg`]: crate::flower::strategy::FitAgg

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::{Duration, Instant};

use crate::flare::tracking::SummaryWriter;
use crate::flower::committee;
use crate::flower::grid::Grid;
use crate::flower::message::{ConfigValue, Message};
use crate::flower::persist::checkpoint::{AsyncCkpt, DriverCkpt, DriverPhase};
use crate::flower::records::{WireCodec, WIRE_CODEC_KEY};
use crate::flower::serverapp::{History, ServerApp};
use crate::flower::strategy::FitRes;

/// Upper bound on [`AsyncConfig::max_staleness`]: the driver
/// pre-computes one weight per staleness value (the strategy is
/// mutably borrowed by its accumulator while results fold), and a lag
/// of thousands of commits means the result is noise anyway.
pub const MAX_MAX_STALENESS: u64 = 4096;

/// Knobs of one asynchronous run. From the sync
/// [`crate::flower::serverapp::ServerConfig`] the driver honours
/// `num_rounds` (one "round" = one commit), `min_nodes`,
/// `accept_failures`, and `round_timeout` (the per-commit deadline).
/// The round-shaped knobs do NOT apply and are ignored: there is no
/// cohort sampling (`fraction_fit`, `seed` — every live node
/// participates each version), no quorum (`min_available`,
/// `straggler_grace` — the buffer is the completion rule), and no
/// federated evaluation (`fraction_evaluate` — no round boundary to
/// evaluate at).
#[derive(Clone, Copy, Debug)]
pub struct AsyncConfig {
    /// Commit a new global model every this many folded results. Must
    /// not exceed the fleet size (each node folds at most once per
    /// version, so a larger buffer could never fill).
    pub buffer_size: usize,
    /// Results lagging the current version by more than this many
    /// commits are dropped instead of folded. 0 = only fresh results
    /// fold (the sync-equivalent setting).
    pub max_staleness: u64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            buffer_size: 2,
            max_staleness: 4,
        }
    }
}

/// One committed global model in an async run's [`History`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AsyncCommit {
    /// Version of the model this commit produced (1-based; version 0 is
    /// the initial model).
    pub version: u64,
    /// Results folded into this commit's buffer.
    pub results_folded: usize,
    /// Largest staleness among them.
    pub max_staleness: u64,
}

/// Verdict of [`AsyncState::offer`] for one arriving result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offer {
    /// Fold it, weighted for this staleness (0 = fresh).
    Fold { staleness: u64 },
    /// Too stale — drop it (does not count as folded).
    DropStale { staleness: u64 },
    /// This task already resolved (redelivery race / duplicate push) —
    /// a result folds at most once.
    DropDuplicate,
}

/// The pure async-fold state machine: staleness gating, per-task
/// dedup, and commit accounting — everything about buffered
/// aggregation that is NOT moving bytes. [`ServerApp::run_async`]
/// drives it against a live SuperLink; `tests/properties.rs` drives it
/// directly with randomized arrival orders, duplicates, and gaps
/// (dead-node tasks that never resolve) to check its invariants.
pub struct AsyncState {
    buffer_size: usize,
    max_staleness: u64,
    version: u64,
    folded_in_window: usize,
    window_max_staleness: u64,
    total_folded: u64,
    commits: u64,
    /// Task ids that already folded (dedup basis).
    done: HashSet<u64>,
}

impl AsyncState {
    pub fn new(buffer_size: usize, max_staleness: u64) -> AsyncState {
        assert!(buffer_size > 0, "async buffer_size must be at least 1");
        AsyncState {
            buffer_size,
            max_staleness,
            version: 0,
            folded_in_window: 0,
            window_max_staleness: 0,
            total_folded: 0,
            commits: 0,
            done: HashSet::new(),
        }
    }

    /// Rebuild the state machine at a commit boundary (what an
    /// [`crate::flower::persist::checkpoint::AsyncCkpt`] records): the
    /// window is empty, and the dedup set starts EMPTY — results folded
    /// into the lost window are replayed by recovery as unclaimed and
    /// must fold again, exactly once.
    pub fn resume(
        buffer_size: usize,
        max_staleness: u64,
        version: u64,
        total_folded: u64,
    ) -> AsyncState {
        assert!(buffer_size > 0, "async buffer_size must be at least 1");
        AsyncState {
            buffer_size,
            max_staleness,
            version,
            folded_in_window: 0,
            window_max_staleness: 0,
            total_folded,
            commits: version,
            done: HashSet::new(),
        }
    }

    /// Current global model version (0 until the first commit).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Results folded into the open window so far.
    pub fn folded_in_window(&self) -> usize {
        self.folded_in_window
    }

    /// Results folded over the whole run.
    pub fn total_folded(&self) -> u64 {
        self.total_folded
    }

    /// Commits performed so far.
    pub fn commits(&self) -> u64 {
        self.commits
    }

    /// The open window holds `buffer_size` results: commit before
    /// offering more.
    pub fn window_full(&self) -> bool {
        self.folded_in_window >= self.buffer_size
    }

    /// Offer one arrived result: `task_id` for dedup, `origin_version`
    /// for staleness (the version the task's parameters were cut from,
    /// stamped authoritatively by the SuperLink). Must not be called
    /// while [`AsyncState::window_full`] — commit first.
    pub fn offer(&mut self, task_id: u64, origin_version: u64) -> Offer {
        assert!(!self.window_full(), "offer() on a full window — commit first");
        if !self.done.insert(task_id) {
            return Offer::DropDuplicate;
        }
        let staleness = self.version.saturating_sub(origin_version);
        if staleness > self.max_staleness {
            return Offer::DropStale { staleness };
        }
        self.folded_in_window += 1;
        self.total_folded += 1;
        self.window_max_staleness = self.window_max_staleness.max(staleness);
        Offer::Fold { staleness }
    }

    /// Drop dedup entries for tasks the caller KNOWS can never be
    /// offered again — the SuperLink stores and hands out each task's
    /// result at most once (`run.done` rejects duplicate pushes), so
    /// the driver prunes every id that already resolved, keeping a
    /// long async run's memory proportional to its in-flight set
    /// rather than its whole history. Callers without such a
    /// transport-level guarantee (e.g. the property-test harness)
    /// simply never prune and keep full dedup.
    pub fn forget_resolved(&mut self, still_unresolved: &HashMap<u64, u64>) {
        self.done.retain(|id| still_unresolved.contains_key(id));
    }

    /// Close the window: bump the global version and return the commit
    /// record (the caller finalizes its accumulator alongside).
    pub fn commit(&mut self) -> AsyncCommit {
        self.version += 1;
        self.commits += 1;
        let rec = AsyncCommit {
            version: self.version,
            results_folded: self.folded_in_window,
            max_staleness: self.window_max_staleness,
        };
        self.folded_in_window = 0;
        self.window_max_staleness = 0;
        rec
    }
}

/// Apply a staleness weight to a result's example count (the weight
/// channel every weighted reduction already honours). Exact identity at
/// `w >= 1.0` — the staleness-0 hot path stays bit-identical to sync —
/// and never rounds a NON-zero weight down to zero. A zero-example
/// result stays zero: it carries no weight fresh, so staleness must
/// not grant it any.
pub fn scale_examples(num_examples: u64, w: f64) -> u64 {
    if w >= 1.0 || num_examples == 0 {
        return num_examples;
    }
    ((num_examples as f64) * w).round().max(1.0) as u64
}

impl ServerApp {
    /// Drive an asynchronous (buffered, staleness-aware) run against
    /// the grid: `ServerConfig::num_rounds` commits, each folding
    /// [`AsyncConfig::buffer_size`] results. Federated evaluation is
    /// not scheduled in async mode (there is no round boundary to
    /// evaluate at); `History::commits` carries the commit log and
    /// `History::parameters` the final model.
    ///
    /// Opens run `run_id` on the grid and finishes it on every exit
    /// path, exactly like the synchronous [`ServerApp::run`].
    pub fn run_async<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
        acfg: AsyncConfig,
    ) -> anyhow::Result<History> {
        anyhow::ensure!(
            self.strategy.supports_async(),
            "strategy {} cannot aggregate asynchronously (e.g. secure aggregation \
             masks are bound to one round cohort) — use the synchronous driver",
            self.strategy.name()
        );
        anyhow::ensure!(acfg.buffer_size > 0, "async buffer_size must be at least 1");
        anyhow::ensure!(
            acfg.max_staleness <= MAX_MAX_STALENESS,
            "max_staleness {} exceeds the supported bound {MAX_MAX_STALENESS}",
            acfg.max_staleness
        );
        grid.open_run(run_id);
        anyhow::ensure!(
            grid.run_active(run_id),
            "run id {run_id} already finished on this link — run ids must be unique per link"
        );
        let state = AsyncState::new(acfg.buffer_size, acfg.max_staleness);
        let result = self.run_commits_from(
            grid,
            tracker,
            run_id,
            &acfg,
            1,
            self.initial_parameters.clone(),
            History::default(),
            state,
        );
        grid.close_run(run_id);
        result
    }

    /// [`ServerApp::run_async`] against a durable grid: on error the run
    /// is left OPEN on the link so a restarted SuperLink can
    /// [`ServerApp::resume_async`] it from the last committed version.
    /// The run is closed only when all commits finish.
    pub fn run_async_durable<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
        acfg: AsyncConfig,
    ) -> anyhow::Result<History> {
        anyhow::ensure!(
            self.strategy.supports_async(),
            "strategy {} cannot aggregate asynchronously (e.g. secure aggregation \
             masks are bound to one round cohort) — use the synchronous driver",
            self.strategy.name()
        );
        anyhow::ensure!(acfg.buffer_size > 0, "async buffer_size must be at least 1");
        anyhow::ensure!(
            acfg.max_staleness <= MAX_MAX_STALENESS,
            "max_staleness {} exceeds the supported bound {MAX_MAX_STALENESS}",
            acfg.max_staleness
        );
        grid.open_run(run_id);
        anyhow::ensure!(
            grid.run_active(run_id),
            "run id {run_id} already finished on this link — run ids must be unique per link"
        );
        let state = AsyncState::new(acfg.buffer_size, acfg.max_staleness);
        let result = self.run_commits_from(
            grid,
            tracker,
            run_id,
            &acfg,
            1,
            self.initial_parameters.clone(),
            History::default(),
            state,
        );
        if result.is_ok() {
            grid.close_run(run_id);
        }
        result
    }

    /// Resume an interrupted async run from its last commit-boundary
    /// driver checkpoint on a recovered link. The window restarts
    /// empty with an EMPTY dedup set: results folded into the lost
    /// window were journaled as accepted after the checkpoint cut, so
    /// recovery replays them as open tasks and they fold again —
    /// exactly once, into the same window they were lost from.
    pub fn resume_async<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        anyhow::ensure!(
            grid.durable(),
            "resume_async needs a durable grid (SuperLink built with checkpoints on)"
        );
        anyhow::ensure!(
            grid.run_active(run_id),
            "run {run_id} is not active on this link — nothing to resume"
        );
        let blob = grid.driver_checkpoint(run_id).ok_or_else(|| {
            anyhow::anyhow!("run {run_id} has no driver checkpoint on this link")
        })?;
        let ck = DriverCkpt::decode(&blob)?;
        let DriverPhase::AsyncCommit(a) = ck.phase else {
            anyhow::bail!(
                "run {run_id} was checkpointed by the synchronous driver — \
                 resume it with ServerApp::resume"
            );
        };
        if let Some(st) = &ck.strategy_state {
            self.strategy.import_state(st)?;
        }
        let acfg = AsyncConfig {
            buffer_size: a.buffer_size as usize,
            max_staleness: a.max_staleness,
        };
        let state = AsyncState::resume(
            a.buffer_size as usize,
            a.max_staleness,
            a.version,
            a.total_folded,
        );
        let result = self.run_commits_from(
            grid,
            tracker,
            run_id,
            &acfg,
            ck.round,
            ck.parameters,
            ck.history,
            state,
        );
        if result.is_ok() {
            grid.close_run(run_id);
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn run_commits_from<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
        acfg: &AsyncConfig,
        start_commit: u64,
        mut params: crate::flower::records::ArrayRecord,
        mut history: History,
        mut state: AsyncState,
    ) -> anyhow::Result<History> {
        // Mirror the synchronous driver's sharding gate: the async
        // buffer is one aggregator that must see every contribution.
        anyhow::ensure!(
            grid.shard_count() == 1 || self.strategy.supports_sharding(),
            "strategy {} cannot aggregate across {} shards (e.g. secure aggregation \
             masks only cancel when one aggregator sees the full cohort) — \
             run it on a single link",
            self.strategy.name(),
            grid.shard_count()
        );
        // Mirror the synchronous driver's codec gates. Additionally,
        // delta encoding binds each reply to the exact model version it
        // was cut from; the driver keeps only the CURRENT parameters,
        // so any staleness window > 0 could admit a delta whose base no
        // longer exists.
        anyhow::ensure!(
            !self.config.codec.is_lossy() || self.strategy.supports_lossy_codec(),
            "strategy {} cannot aggregate lossy '{}' wire-codec results \
             (e.g. secure aggregation masks do not survive quantization) — \
             use the identity or delta codec",
            self.strategy.name(),
            self.config.codec.name()
        );
        anyhow::ensure!(
            self.config.codec != WireCodec::Delta || acfg.max_staleness == 0,
            "delta wire codec requires max_staleness == 0: a result lagging the \
             current version deltas against a model the driver no longer holds"
        );
        // Mirror the synchronous driver's committee gate: quarantining
        // excludes an arrived contribution from the fold, which only a
        // byzantine-tolerant strategy can absorb.
        anyhow::ensure!(
            self.config.committee.is_none() || self.strategy.supports_byzantine(),
            "strategy {} cannot aggregate a committee-filtered cohort (e.g. secure \
             aggregation masks only cancel when every contribution folds) — \
             disable committee validation",
            self.strategy.name()
        );
        let cfg = self.config.clone();
        let nodes = grid.wait_for_nodes(cfg.min_nodes, cfg.round_timeout)?;
        anyhow::ensure!(
            acfg.buffer_size <= nodes.len(),
            "async buffer_size {} exceeds the fleet of {} nodes — each node folds \
             at most once per version, so the buffer could never fill",
            acfg.buffer_size,
            nodes.len()
        );
        // Weights are pre-computed per staleness value because the
        // strategy is mutably borrowed by its accumulator while results
        // fold (and staleness_weight is pure).
        let weights: Vec<f64> = (0..=acfg.max_staleness)
            .map(|d| self.strategy.staleness_weight(d))
            .collect();
        let accept_failures = cfg.accept_failures;
        let durable = grid.durable();
        // task_id -> assigned node, for every unresolved dispatch.
        let mut outstanding: HashMap<u64, u64> = HashMap::new();
        // Nodes with an unresolved task (at most one each).
        let mut busy: HashSet<u64> = HashSet::new();
        // node -> last version dispatched to it (one task per version).
        let mut last_version: HashMap<u64, u64> = HashMap::new();
        // Reconcile with the link: after recovery every open task
        // (re-queued, in flight, or accepted-but-unclaimed) is an
        // outstanding dispatch from this driver's point of view, pinned
        // to the model version it was cut from. Fresh runs have no open
        // tasks, so this is a no-op for them.
        for (task_id, node_id, version) in grid.open_tasks(run_id) {
            outstanding.insert(task_id, node_id);
            busy.insert(node_id);
            last_version.insert(node_id, version);
        }
        // Claimed-but-unfolded replies: pull_messages can hand over more
        // than the open window needs; the excess carries into the next
        // window (its staleness re-evaluated against the new version).
        let mut ready: VecDeque<Message> = VecDeque::new();
        if durable {
            // Cut the entry checkpoint so a crash inside the FIRST
            // window after (re)start still has a commit boundary to
            // resume from.
            let ck = DriverCkpt {
                round: start_commit,
                parameters: params.clone(),
                history: history.clone(),
                strategy_state: self.strategy.export_state(),
                phase: DriverPhase::AsyncCommit(AsyncCkpt {
                    buffer_size: acfg.buffer_size as u64,
                    max_staleness: acfg.max_staleness,
                    version: state.version(),
                    total_folded: state.total_folded(),
                }),
            };
            grid.checkpoint_run(run_id, ck.encode());
        }

        for commit in start_commit..=cfg.num_rounds {
            let deadline = Instant::now() + cfg.round_timeout;
            // Per-version fit config, computed while no accumulator
            // borrows the strategy.
            let mut fit_cfg = self.strategy.configure_fit(commit);
            fit_cfg.push(("round".to_string(), ConfigValue::I64(commit as i64)));
            // Negotiate the uplink codec (see the sync driver).
            if cfg.codec != WireCodec::Identity {
                fit_cfg.push((
                    WIRE_CODEC_KEY.to_string(),
                    ConfigValue::Str(cfg.codec.name().to_string()),
                ));
            }
            let mut agg = self.strategy.begin_fit(commit, &params);
            // With committee validation on, the window's results defer
            // here instead of folding eagerly: the committee needs the
            // FULL buffer to elect members and score outliers, so
            // survivors fold only once the window closes.
            let mut pending: Vec<FitRes> = Vec::new();
            loop {
                grid.reap();
                // Fold claimed results until the window fills.
                while !state.window_full() {
                    let Some(res) = ready.pop_front() else { break };
                    let node = res.metadata.src_node_id;
                    if !res.error.is_empty() {
                        crate::telemetry::bump("asyncfed.client_errors", 1);
                        if accept_failures {
                            log::warn!(
                                "async commit {commit}: node {node} failed: {}",
                                res.error
                            );
                            continue;
                        }
                        anyhow::bail!(
                            "async commit {commit}: node {node} failed: {}",
                            res.error
                        );
                    }
                    match state.offer(res.metadata.message_id, res.metadata.model_version) {
                        Offer::Fold { staleness } => {
                            let task_id = res.metadata.message_id;
                            // Delta replies resolve against the current
                            // parameters; the staleness-0 gate above
                            // guarantees any FOLDED delta was cut from
                            // exactly this version.
                            let arrays = match res
                                .content
                                .arrays
                                .resolve_delta(&params, res.metadata.model_version)
                            {
                                Ok(a) => a,
                                Err(e) => {
                                    crate::telemetry::bump("asyncfed.client_errors", 1);
                                    if accept_failures {
                                        log::warn!(
                                            "async commit {commit}: node {node} refused: {e}"
                                        );
                                        continue;
                                    }
                                    anyhow::bail!(
                                        "async commit {commit}: node {node} refused: {e}"
                                    );
                                }
                            };
                            let fit_res = FitRes {
                                node_id: node,
                                parameters: arrays,
                                num_examples: scale_examples(
                                    res.metadata.num_examples,
                                    weights[staleness as usize],
                                ),
                                metrics: res.content.metrics,
                            };
                            if cfg.committee.is_some() {
                                pending.push(fit_res);
                            } else {
                                agg.accumulate(fit_res)?;
                            }
                            if durable {
                                grid.journal_fold(run_id, task_id);
                            }
                        }
                        Offer::DropStale { staleness } => {
                            crate::telemetry::bump("asyncfed.stale_results_dropped", 1);
                            log::warn!(
                                "async commit {commit}: dropped result from node {node} \
                                 (staleness {staleness} > {})",
                                acfg.max_staleness
                            );
                        }
                        Offer::DropDuplicate => {
                            crate::telemetry::bump("asyncfed.duplicate_results_dropped", 1);
                        }
                    }
                }
                if state.window_full() {
                    break;
                }
                // Keep the fleet saturated: dispatch the CURRENT model
                // to every idle node that has not yet trained this
                // version.
                for node in grid.node_ids() {
                    if busy.contains(&node)
                        || last_version.get(&node).copied() == Some(state.version())
                    {
                        continue;
                    }
                    let mut config = fit_cfg.clone();
                    config.push(("node_id".to_string(), ConfigValue::I64(node as i64)));
                    // Node-affine, like every FL fit task; tagged with
                    // the model version the parameters were cut from.
                    let task_id = grid.push_message(
                        Message::train(node, params.clone(), config)
                            .for_round(run_id, commit)
                            .with_model_version(state.version()),
                    );
                    busy.insert(node);
                    last_version.insert(node, state.version());
                    outstanding.insert(task_id, node);
                }
                // Claim whatever resolved — never barrier on a cohort.
                let ids: Vec<u64> = outstanding.keys().copied().collect();
                let (got, failed) = grid.pull_messages(run_id, &ids);
                let progressed = !got.is_empty();
                for res in got {
                    if let Some(node) = outstanding.remove(&res.metadata.message_id) {
                        busy.remove(&node);
                    }
                    ready.push_back(res);
                }
                for (task_id, reason) in failed {
                    if let Some(node) = outstanding.remove(&task_id) {
                        busy.remove(&node);
                    }
                    crate::telemetry::bump("asyncfed.tasks_failed", 1);
                    log::warn!("async commit {commit}: task {task_id} failed: {reason}");
                }
                if progressed {
                    continue; // fold before sleeping
                }
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "async commit {commit}: timed out with {}/{} results folded",
                    state.folded_in_window(),
                    acfg.buffer_size
                );
                anyhow::ensure!(
                    !outstanding.is_empty() || !grid.node_ids().is_empty(),
                    "async commit {commit}: no live nodes remain ({}/{} results folded)",
                    state.folded_in_window(),
                    acfg.buffer_size
                );
                // Unfillable window: nothing in flight, nothing queued,
                // and every live node already contributed to the
                // current version (its remaining supply was consumed by
                // tolerated client errors or staleness drops). Waiting
                // out the deadline cannot help — fail with the cause.
                if outstanding.is_empty()
                    && ready.is_empty()
                    && grid
                        .node_ids()
                        .iter()
                        .all(|n| last_version.get(n).copied() == Some(state.version()))
                {
                    anyhow::bail!(
                        "async commit {commit}: stalled at {}/{} results — every live \
                         node already trained version {} and no task is in flight \
                         (client errors or stale drops consumed the version's supply)",
                        state.folded_in_window(),
                        acfg.buffer_size,
                        state.version()
                    );
                }
                grid.wait_activity_run(run_id, Duration::from_millis(50));
            }
            // Window closed: committee-validate the buffered results
            // and fold the survivors in node-id order (the accumulator
            // canonicalizes anyway — the sort keeps folding order
            // deterministic for non-canonicalizing accumulators too).
            if let Some(cc) = &cfg.committee {
                let verdicts = committee::validate(cc, cfg.seed, run_id, commit, &pending);
                let quarantined = committee::quarantined_nodes(&verdicts);
                pending.sort_by_key(|r| r.node_id);
                for fit_res in pending.drain(..) {
                    if quarantined.contains(&fit_res.node_id) {
                        continue;
                    }
                    agg.accumulate(fit_res)?;
                }
                anyhow::ensure!(
                    agg.count() > 0,
                    "async commit {commit}: committee quarantined every buffered update"
                );
            }
            params = agg.finalize()?;
            let rec = state.commit();
            if durable {
                grid.journal_commit(run_id, rec.version);
            }
            // Commit-boundary housekeeping: dedup ids that already
            // resolved can never arrive again (link-level dedup), and
            // version bookkeeping for reaped nodes is dead weight — a
            // rejoining node starts a fresh entry anyway.
            state.forget_resolved(&outstanding);
            let live: HashSet<u64> = grid.node_ids().into_iter().collect();
            last_version.retain(|node, _| live.contains(node) || busy.contains(node));
            if let Some(t) = tracker {
                t.add_scalar("async_results_folded", rec.results_folded as f64, commit);
                t.add_scalar("async_max_staleness", rec.max_staleness as f64, commit);
            }
            log::info!(
                "async commit {}: version {} from {} results (max staleness {})",
                commit,
                rec.version,
                rec.results_folded,
                rec.max_staleness
            );
            history.commits.push(rec);
            // Commit-boundary checkpoint — at EVERY commit, not on the
            // link's result-count cadence: resume restores the
            // checkpointed version, and any older boundary would leave
            // replayed results with origins NEWER than the restored
            // version. Only cut while `ready` is empty: a
            // claimed-but-unfolded result is gone from the link's
            // snapshot but not yet in any window, so a checkpoint here
            // would lose it. (With the durable link's one-result claim
            // limit the queue always drains before the window fills, so
            // this never skips in practice.)
            if durable && ready.is_empty() {
                let ck = DriverCkpt {
                    round: commit + 1,
                    parameters: params.clone(),
                    history: history.clone(),
                    strategy_state: self.strategy.export_state(),
                    phase: DriverPhase::AsyncCommit(AsyncCkpt {
                        buffer_size: acfg.buffer_size as u64,
                        max_staleness: acfg.max_staleness,
                        version: state.version(),
                        total_folded: state.total_folded(),
                    }),
                };
                grid.checkpoint_run(run_id, ck.encode());
            }
        }
        history.parameters = params;
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::clientapp::{ArithmeticClient, ClientApp};
    use std::sync::Arc;
    use crate::flower::records::ArrayRecord;
    use crate::flower::run::NativeFleet;
    use crate::flower::serverapp::ServerConfig;
    use crate::flower::strategy::{Aggregator, FedAvg};

    #[test]
    fn state_commits_every_buffer_size_folds() {
        let mut st = AsyncState::new(2, 3);
        assert_eq!(st.version(), 0);
        assert_eq!(st.offer(1, 0), Offer::Fold { staleness: 0 });
        assert!(!st.window_full());
        assert_eq!(st.offer(2, 0), Offer::Fold { staleness: 0 });
        assert!(st.window_full());
        let c = st.commit();
        assert_eq!(
            c,
            AsyncCommit {
                version: 1,
                results_folded: 2,
                max_staleness: 0
            }
        );
        // Staleness is measured against the CURRENT version at fold
        // time: a version-0 result now lags by 1.
        assert_eq!(st.offer(3, 0), Offer::Fold { staleness: 1 });
        assert_eq!(st.offer(4, 1), Offer::Fold { staleness: 0 });
        let c = st.commit();
        assert_eq!(c.version, 2);
        assert_eq!(c.max_staleness, 1);
        assert_eq!(st.total_folded(), 4);
        assert_eq!(st.commits(), 2);
    }

    #[test]
    fn state_drops_duplicates_and_stale_results() {
        let mut st = AsyncState::new(8, 1);
        assert_eq!(st.offer(1, 0), Offer::Fold { staleness: 0 });
        // Redelivery race: the same task id never folds twice.
        assert_eq!(st.offer(1, 0), Offer::DropDuplicate);
        // Simulate two commits elapsing.
        st.commit();
        st.commit();
        assert_eq!(st.version(), 2);
        assert_eq!(st.offer(2, 0), Offer::DropStale { staleness: 2 });
        assert_eq!(st.offer(3, 1), Offer::Fold { staleness: 1 });
        // Dropped results count toward neither folds nor dedup-exempt:
        // a duplicate of a DROPPED task is still a duplicate.
        assert_eq!(st.offer(2, 2), Offer::DropDuplicate);
        assert_eq!(st.total_folded(), 2);
    }

    #[test]
    fn scale_examples_is_identity_at_unit_weight() {
        assert_eq!(scale_examples(12345, 1.0), 12345);
        assert_eq!(scale_examples(u64::MAX, 1.0), u64::MAX, "no f64 roundtrip at w=1");
        assert_eq!(scale_examples(100, 0.5), 50);
        // A folded result's non-zero weight never rounds down to zero.
        assert_eq!(scale_examples(1, 0.01), 1);
        // A zero-weight result must not GAIN weight by going stale.
        assert_eq!(scale_examples(0, 0.5), 0);
    }

    fn apps(deltas: &[(f32, u64)]) -> Vec<Arc<dyn ClientApp>> {
        deltas
            .iter()
            .map(|&(delta, n)| Arc::new(ArithmeticClient { delta, n }) as Arc<dyn ClientApp>)
            .collect()
    }

    #[test]
    fn async_run_commits_and_respects_staleness_bound() {
        let fleet = NativeFleet::start(apps(&[(1.0, 10), (2.0, 20), (3.0, 30)])).unwrap();
        let mut app = ServerApp::new(
            Box::new(FedAvg::new(Aggregator::host())),
            ServerConfig {
                num_rounds: 4, // = commits in async mode
                min_nodes: 3,
                seed: 21,
                ..Default::default()
            },
            ArrayRecord::from_flat(&[0.0f32; 6]),
        );
        let h = app
            .run_async(
                fleet.link(),
                None,
                1,
                AsyncConfig {
                    buffer_size: 2,
                    max_staleness: 4,
                },
            )
            .unwrap();
        fleet.shutdown();
        assert_eq!(h.commits.len(), 4, "one commit per configured round");
        for (i, c) in h.commits.iter().enumerate() {
            assert_eq!(c.version, i as u64 + 1);
            assert_eq!(c.results_folded, 2, "commit {i} fold count");
            assert!(c.max_staleness <= 4, "commit {i} staleness bound");
        }
        assert!(h.rounds.is_empty(), "async mode records commits, not rounds");
        // The model moved: 8 folded results, every delta positive.
        assert!(h.parameters.to_flat().iter().all(|&p| p > 0.0));
    }

    #[test]
    fn async_refuses_buffer_larger_than_fleet() {
        let fleet = NativeFleet::start(apps(&[(1.0, 10), (2.0, 20)])).unwrap();
        let mut app = ServerApp::new(
            Box::new(FedAvg::new(Aggregator::host())),
            ServerConfig {
                num_rounds: 1,
                min_nodes: 2,
                ..Default::default()
            },
            ArrayRecord::from_flat(&[0.0f32; 2]),
        );
        let err = app
            .run_async(
                fleet.link(),
                None,
                1,
                AsyncConfig {
                    buffer_size: 3,
                    max_staleness: 0,
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("exceeds the fleet"), "{err}");
        fleet.shutdown();
    }
}
