//! Federated analytics: a workload built ONLY on [`MessageType::Query`]
//! messages — no model, no strategy, no parameters anywhere. This is
//! the scenario axis the generic Message API opens (Flower "is
//! dedicated to implementing a cohesive approach to FL, **analytics**,
//! and evaluation"): the same SuperLink/SuperNode/bridge layers that
//! move fit traffic move these queries without a line of them changing.
//!
//! The workload: a **federated histogram + weighted quantile sketch**
//! over the clients' local datasets. The driver broadcasts the sketch
//! grid (`bins`, `lo`, `hi`) in a Query message; each client answers
//! with its local per-bin counts (exact i64) and per-bin weight sums
//! (f64, accumulated in local index order); the driver merges replies
//! in **node-id order** and extracts quantiles from the merged weighted
//! CDF. Counts merge exactly; weight sums are reduced in canonical
//! order — so the report is **bit-identical** across transports
//! (native vs bridged Grid) and arrival orders, the same determinism
//! contract the FL path holds (Fig. 5, for analytics).
//!
//! Raw values never leave a client — only its bin totals do (the
//! classic federated-analytics privacy posture).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::flower::clientapp::{Context, Router};
use crate::flower::grid::Grid;
use crate::flower::message::{ConfigRecord, ConfigValue, Message, MessageType};
use crate::flower::records::{DType, RecordDict, Tensor};
use crate::flower::superlink::CompletionPolicy;

/// Tensor name for per-bin counts in a query reply.
pub const HIST_COUNTS: &str = "hist_counts";
/// Tensor name for per-bin weight sums in a query reply.
pub const HIST_WEIGHTS: &str = "hist_weights";
/// Largest sketch a node will compute. The bin count arrives from the
/// wire, so — like every decode limit in `flower::message` — it must be
/// bounded BEFORE allocation: a hostile `bins` of 2^40 would otherwise
/// abort the node on an 8 TiB `vec![]` instead of yielding the typed
/// error reply the handler contract guarantees.
pub const MAX_QUERY_BINS: usize = 1 << 20;

/// One analytics run's knobs: the sketch grid and the quantiles to
/// extract from the merged CDF.
#[derive(Clone, Debug)]
pub struct AnalyticsConfig {
    /// Number of histogram bins over `[lo, hi)`; out-of-range values
    /// clamp into the edge bins.
    pub bins: usize,
    pub lo: f64,
    pub hi: f64,
    /// Quantile ranks to extract (e.g. 0.5 = weighted median).
    pub quantiles: Vec<f64>,
    /// Wait for at least this many nodes before querying.
    pub min_nodes: usize,
    pub timeout: Duration,
}

impl Default for AnalyticsConfig {
    fn default() -> Self {
        Self {
            bins: 16,
            lo: 0.0,
            hi: 1.0,
            quantiles: vec![0.25, 0.5, 0.75, 0.9],
            min_nodes: 1,
            timeout: Duration::from_secs(30),
        }
    }
}

impl AnalyticsConfig {
    /// The sketch grid as the Query message's config payload.
    fn to_config(&self) -> ConfigRecord {
        let mut c = ConfigRecord::new();
        c.insert("bins", ConfigValue::I64(self.bins as i64));
        c.insert("lo", ConfigValue::F64(self.lo));
        c.insert("hi", ConfigValue::F64(self.hi));
        c
    }
}

/// The merged federation-wide answer.
#[derive(Clone, Debug, PartialEq)]
pub struct AnalyticsReport {
    pub bins: usize,
    pub lo: f64,
    pub hi: f64,
    /// Exact merged per-bin counts.
    pub histogram: Vec<i64>,
    /// Merged per-bin weight sums (reduced in node-id order).
    pub bin_weights: Vec<f64>,
    /// (rank, value) per requested quantile, from the weighted CDF.
    pub quantiles: Vec<(f64, f64)>,
    /// Total examples across answering nodes.
    pub total_examples: u64,
    /// Nodes whose replies were merged, ascending.
    pub nodes_answered: Vec<u64>,
    /// Per-node failures the driver surfaced (node id, error) — e.g. a
    /// node with no Query handler answers with a typed
    /// [`crate::flower::clientapp::UNHANDLED_MESSAGE_ERR`] reply.
    pub per_node_errors: Vec<(u64, String)>,
}

impl AnalyticsReport {
    /// Bit-exact equality (f64 compared by bit pattern — the
    /// native-vs-bridged overlay check).
    pub fn bits_equal(&self, other: &AnalyticsReport) -> bool {
        self.bins == other.bins
            && self.histogram == other.histogram
            && self.total_examples == other.total_examples
            && self.nodes_answered == other.nodes_answered
            && self.bin_weights.len() == other.bin_weights.len()
            && self
                .bin_weights
                .iter()
                .zip(other.bin_weights.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self.quantiles.len() == other.quantiles.len()
            && self
                .quantiles
                .iter()
                .zip(other.quantiles.iter())
                .all(|(a, b)| a.0.to_bits() == b.0.to_bits() && a.1.to_bits() == b.1.to_bits())
    }
}

/// Client side: answers `Query` messages with the local histogram /
/// weight sketch over `values` (each a `(value, weight)` pair). Mount
/// with [`HistogramQueryApp::router`]; raw values never leave the node.
pub struct HistogramQueryApp {
    pub values: Vec<(f64, f64)>,
}

impl HistogramQueryApp {
    /// A [`Router`] serving ONLY `Query` — pushing a Train message at
    /// this app yields the typed unhandled-type error reply, proving
    /// the workload really carries no model path.
    pub fn router(self) -> Router {
        let data = Arc::new(self.values);
        Router::new().on_query(move |msg: &Message, ctx: &mut Context| {
            local_sketch(&data, msg, ctx)
        })
    }
}

/// Compute one node's reply: exact local bin counts + local weight
/// sums over the sketch grid the query carries.
fn local_sketch(
    values: &[(f64, f64)],
    msg: &Message,
    ctx: &mut Context,
) -> anyhow::Result<Message> {
    anyhow::ensure!(
        msg.content.arrays.is_empty(),
        "analytics query must carry no tensors (got {})",
        msg.content.arrays.len()
    );
    let cfg = &msg.content.configs;
    let bins = cfg.get_i64("bins").unwrap_or(0).max(0) as usize;
    let lo = cfg.get_f64("lo").unwrap_or(0.0);
    let hi = cfg.get_f64("hi").unwrap_or(1.0);
    anyhow::ensure!(bins > 0, "query missing a positive 'bins'");
    anyhow::ensure!(
        bins <= MAX_QUERY_BINS,
        "query asks for {bins} bins, limit is {MAX_QUERY_BINS}"
    );
    anyhow::ensure!(hi > lo, "query needs hi > lo (got {lo}..{hi})");
    let width = (hi - lo) / bins as f64;
    let mut counts = vec![0i64; bins];
    let mut weights = vec![0f64; bins];
    // Local index order: deterministic per node regardless of transport.
    for &(v, w) in values {
        let idx = (((v - lo) / width).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        counts[idx] += 1;
        weights[idx] += w;
    }
    // Persistent per-run context: how many queries this node answered
    // (round N's count is visible in round N+1).
    let answered = ctx.state.bump("queries_answered", 1);
    let content = RecordDict {
        arrays: crate::flower::records::ArrayRecord::from_tensors(vec![
            Tensor::from_i64(HIST_COUNTS, vec![bins], &counts),
            Tensor::from_f64(HIST_WEIGHTS, vec![bins], &weights),
        ])?,
        metrics: vec![("queries_answered".to_string(), answered as f64)].into(),
        configs: ConfigRecord::new(),
    };
    Ok(msg.reply(content).with_examples(values.len() as u64))
}

/// Drive one federated query round: broadcast the sketch grid to every
/// live node, merge replies in node-id order, extract quantiles.
/// Per-node failures (handler errors, dead nodes) are SURFACED in
/// [`AnalyticsReport::per_node_errors`]; the run only errors out when
/// no node answered at all.
///
/// Works against any [`Grid`] — pass `&link` natively or a
/// [`crate::bridge::BridgedGrid`] inside FLARE; the report is
/// bit-identical either way.
pub fn run_query<G: Grid + ?Sized>(
    grid: &G,
    run_id: u64,
    cfg: &AnalyticsConfig,
) -> anyhow::Result<AnalyticsReport> {
    grid.open_run(run_id);
    anyhow::ensure!(
        grid.run_active(run_id),
        "run id {run_id} already finished on this grid — run ids must be unique"
    );
    let result = query_round(grid, run_id, cfg);
    grid.close_run(run_id);
    result
}

fn query_round<G: Grid + ?Sized>(
    grid: &G,
    run_id: u64,
    cfg: &AnalyticsConfig,
) -> anyhow::Result<AnalyticsReport> {
    anyhow::ensure!(cfg.bins > 0, "analytics needs at least one bin");
    anyhow::ensure!(
        cfg.bins <= MAX_QUERY_BINS,
        "analytics config asks for {} bins, limit is {MAX_QUERY_BINS}",
        cfg.bins
    );
    anyhow::ensure!(cfg.hi > cfg.lo, "analytics needs hi > lo");
    let nodes = grid.wait_for_nodes(cfg.min_nodes, cfg.timeout)?;
    let query_cfg = cfg.to_config();
    let msgs: Vec<Message> = nodes
        .iter()
        .map(|&node| {
            let m = Message::query(node, query_cfg.clone()).for_round(run_id, 1);
            // The zero-model contract, enforced at the source.
            debug_assert!(m.content.arrays.is_empty());
            debug_assert_eq!(m.message_type, MessageType::Query);
            m
        })
        .collect();
    let ids = grid.push_messages(msgs);
    let id_to_node: HashMap<u64, u64> = ids.iter().copied().zip(nodes.iter().copied()).collect();

    // Buffer replies, then merge in canonical (node-id) order so the
    // f64 weight reduction is arrival-order- and transport-independent.
    let mut replies: Vec<(u64, Vec<i64>, Vec<f64>, u64)> = Vec::new();
    let mut per_node_errors: Vec<(u64, String)> = Vec::new();
    let wait = grid.for_each_reply(
        run_id,
        &ids,
        cfg.timeout,
        // Every node must resolve (reply or fail) — failures become
        // per-node data below, not round errors.
        CompletionPolicy::quorum(1, cfg.timeout),
        &mut |m: Message| {
            let node = m.metadata.src_node_id;
            if !m.error.is_empty() {
                per_node_errors.push((node, m.error));
                return Ok(());
            }
            // A malformed (but "successful") reply is a PER-NODE
            // failure like any other — it must not abort the round and
            // discard every healthy node's answer.
            let (counts, weights) = match (
                m.content.arrays.get(HIST_COUNTS),
                m.content.arrays.get(HIST_WEIGHTS),
            ) {
                (Some(c), Some(w))
                    if c.dtype() == DType::I64
                        && w.dtype() == DType::F64
                        && c.elems() == cfg.bins
                        && w.elems() == cfg.bins =>
                {
                    (c, w)
                }
                _ => {
                    per_node_errors.push((
                        node,
                        format!(
                            "malformed sketch reply (need {HIST_COUNTS} i64[{bins}] + \
                             {HIST_WEIGHTS} f64[{bins}])",
                            bins = cfg.bins
                        ),
                    ));
                    return Ok(());
                }
            };
            let c: Vec<i64> = (0..cfg.bins)
                .map(|i| counts.get_bits_u64(i) as i64)
                .collect();
            let w: Vec<f64> = (0..cfg.bins).map(|i| weights.get_f64(i)).collect();
            replies.push((node, c, w, m.metadata.num_examples));
            Ok(())
        },
    )?;
    for (task_id, reason) in wait.failed {
        per_node_errors.push((id_to_node.get(&task_id).copied().unwrap_or(0), reason));
    }
    for task_id in wait.missing {
        per_node_errors.push((
            id_to_node.get(&task_id).copied().unwrap_or(0),
            "no reply before the deadline".to_string(),
        ));
    }
    per_node_errors.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
    if replies.is_empty() {
        let detail = per_node_errors
            .iter()
            .map(|(n, e)| format!("node {n}: {e}"))
            .collect::<Vec<_>>()
            .join("; ");
        anyhow::bail!("query run {run_id}: no node answered ({detail})");
    }

    // Canonical merge order.
    replies.sort_by_key(|(node, _, _, _)| *node);
    let mut histogram = vec![0i64; cfg.bins];
    let mut bin_weights = vec![0f64; cfg.bins];
    let mut total_examples = 0u64;
    let mut nodes_answered = Vec::with_capacity(replies.len());
    for (node, counts, weights, examples) in &replies {
        nodes_answered.push(*node);
        total_examples += examples;
        for (h, c) in histogram.iter_mut().zip(counts) {
            *h += c;
        }
        for (bw, w) in bin_weights.iter_mut().zip(weights) {
            *bw += w;
        }
    }
    let quantiles = cfg
        .quantiles
        .iter()
        .map(|&q| (q, weighted_quantile(&bin_weights, cfg.lo, cfg.hi, q)))
        .collect();
    Ok(AnalyticsReport {
        bins: cfg.bins,
        lo: cfg.lo,
        hi: cfg.hi,
        histogram,
        bin_weights,
        quantiles,
        total_examples,
        nodes_answered,
        per_node_errors,
    })
}

/// Extract quantile `q` from a per-bin weight CDF over `[lo, hi)`,
/// interpolating linearly within the bin that crosses the target mass.
fn weighted_quantile(bin_weights: &[f64], lo: f64, hi: f64, q: f64) -> f64 {
    let total: f64 = bin_weights.iter().sum();
    if total <= 0.0 {
        return lo;
    }
    let width = (hi - lo) / bin_weights.len() as f64;
    let target = q.clamp(0.0, 1.0) * total;
    let mut cum = 0.0;
    for (i, &w) in bin_weights.iter().enumerate() {
        if cum + w >= target {
            let frac = if w > 0.0 { (target - cum) / w } else { 0.0 };
            return lo + width * (i as f64 + frac);
        }
        cum += w;
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::message::FlowerMsg;
    use crate::flower::superlink::SuperLink;

    #[test]
    fn local_sketch_bins_and_clamps() {
        let app = HistogramQueryApp {
            values: vec![(0.05, 1.0), (0.05, 2.0), (0.95, 1.0), (-3.0, 5.0), (9.0, 1.0)],
        };
        let router = app.router();
        let mut ctx = Context::new(1, 4);
        let q = Message::query(
            4,
            AnalyticsConfig {
                bins: 10,
                ..Default::default()
            }
            .to_config(),
        );
        use crate::flower::clientapp::MessageApp;
        let reply = router.handle(&q, &mut ctx).unwrap();
        let counts = reply.content.arrays.get(HIST_COUNTS).unwrap();
        let weights = reply.content.arrays.get(HIST_WEIGHTS).unwrap();
        // Bin 0: the two 0.05s plus the clamped -3.0; bin 9: 0.95 plus
        // the clamped 9.0.
        assert_eq!(counts.get_bits_u64(0) as i64, 3);
        assert_eq!(counts.get_bits_u64(9) as i64, 2);
        assert_eq!(weights.get_f64(0), 8.0);
        assert_eq!(weights.get_f64(9), 2.0);
        assert_eq!(reply.metadata.num_examples, 5);
        // Context counter persists.
        let reply2 = router.handle(&q, &mut ctx).unwrap();
        assert_eq!(reply2.content.metrics.get("queries_answered"), Some(2.0));
    }

    #[test]
    fn sketch_refuses_model_payloads_and_bad_grids() {
        let router = HistogramQueryApp { values: vec![] }.router();
        let mut ctx = Context::new(1, 1);
        use crate::flower::clientapp::MessageApp;
        let mut with_tensor = Message::query(1, AnalyticsConfig::default().to_config());
        with_tensor.content.arrays = crate::flower::records::ArrayRecord::from_flat(&[1.0]);
        assert!(router.handle(&with_tensor, &mut ctx).is_err());
        let no_bins = Message::query(1, ConfigRecord::new());
        assert!(router.handle(&no_bins, &mut ctx).is_err());
        // A hostile bin count is refused BEFORE allocation (typed error,
        // not an aborted node).
        let mut huge = ConfigRecord::new();
        huge.insert("bins", ConfigValue::I64(1 << 40));
        let err = router
            .handle(&Message::query(1, huge), &mut ctx)
            .unwrap_err();
        assert!(err.to_string().contains("limit"), "{err}");
    }

    #[test]
    fn weighted_quantile_interpolates() {
        // Two equal-weight bins over [0, 1): median sits at the bin
        // boundary, q=0.25 in the middle of bin 0.
        let w = vec![1.0, 1.0];
        assert_eq!(weighted_quantile(&w, 0.0, 1.0, 0.25), 0.25);
        assert_eq!(weighted_quantile(&w, 0.0, 1.0, 0.5), 0.5);
        assert_eq!(weighted_quantile(&w, 0.0, 1.0, 1.0), 1.0);
        assert_eq!(weighted_quantile(&[0.0, 0.0], 0.0, 1.0, 0.5), 0.0);
    }

    /// Answer every queued query on the link by hand (no SuperNode):
    /// lets the unit test drive `run_query` against a live link
    /// synchronously. Returns how many queries were answered.
    fn answer_queries(link: &SuperLink, node_id: u64, app_values: &[(f64, f64)]) -> usize {
        let pull = link.handle_frame(&FlowerMsg::PullTaskIns { node_id }.encode());
        let tasks = match FlowerMsg::decode(&pull).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => tasks,
            other => panic!("{other:?}"),
        };
        let mut ctx = Context::new(0, node_id);
        let n = tasks.len();
        for ins in tasks {
            let msg = Message::from_ins(ins, node_id);
            let reply = local_sketch(app_values, &msg, &mut ctx).unwrap();
            link.handle_frame(&FlowerMsg::PushTaskRes { res: reply.into_res() }.encode());
        }
        n
    }

    #[test]
    fn run_query_merges_in_node_order_and_reports_errors() {
        let link = SuperLink::new();
        for _ in 0..2 {
            link.handle_frame(&FlowerMsg::CreateNode { requested: 0 }.encode());
        }
        let cfg = AnalyticsConfig {
            bins: 4,
            lo: 0.0,
            hi: 4.0,
            quantiles: vec![0.5],
            min_nodes: 2,
            timeout: Duration::from_secs(5),
        };
        // Drive the round from a thread; answer from this one.
        let l2 = link.clone();
        let cfg2 = cfg.clone();
        let h = std::thread::spawn(move || run_query(&l2, 1, &cfg2));
        // Keep pulling until both nodes' queries arrived and were
        // answered (the driver thread races this loop).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut total = 0;
        while total < 2 {
            assert!(std::time::Instant::now() < deadline, "queries never arrived");
            total += answer_queries(&link, 1, &[(0.5, 1.0), (1.5, 1.0)]);
            total += answer_queries(&link, 2, &[(2.5, 2.0)]);
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = h.join().unwrap().unwrap();
        assert_eq!(report.histogram, vec![1, 1, 1, 0]);
        assert_eq!(report.bin_weights, vec![1.0, 1.0, 2.0, 0.0]);
        assert_eq!(report.total_examples, 3);
        assert_eq!(report.nodes_answered, vec![1, 2]);
        assert!(report.per_node_errors.is_empty());
        // Median of weights [1,1,2] over [0,4): target 2.0 -> end of
        // bin 1.
        assert_eq!(report.quantiles, vec![(0.5, 2.0)]);
    }
}
