//! Flower wire protocol: the frames exchanged between a SuperNode and
//! the SuperLink (paper §3.2). Mirrors Flower's TaskIns/TaskRes model:
//! clients *pull* task instructions and *push* task results.
//!
//! These bytes are what the FLARE bridge forwards opaquely (§4.2) — the
//! Fig. 5 bit-exactness claim rests on this codec being used identically
//! on the native and bridged paths.
//!
//! ## Frame versions
//!
//! * **v2 (current)** — first byte is [`FRAME_MAGIC_V2`]; parameters are
//!   an [`ArrayRecord`] encoded as length-prefixed tensor segments
//!   (name, dtype, shape, payload bytes). Decoding is **zero-copy**:
//!   [`FlowerMsg::decode_shared`] hands each tensor a [`Bytes`] view of
//!   the received frame buffer — no payload bytes are copied.
//! * **v1 (legacy)** — first byte is the message tag; parameters are a
//!   flat `f32` vector. [`FlowerMsg::decode`] transparently accepts v1
//!   frames (wrapping the flat vector via [`ArrayRecord::from_flat`]),
//!   and [`FlowerMsg::encode_v1`] emits them for old peers (lossy for
//!   records that are not a single flat f32 tensor).
//!
//! All decode limits are named constants below; oversized or
//! structurally invalid frames return [`WireError`] — never panic, never
//! allocate unbounded memory.

use crate::flower::records::{ArrayRecord, DType, Encoding, RecordDict, Tensor};
use crate::util::bytes::{Bytes, FrameReader, Reader, WireError, Writer};

pub use crate::flower::records::{
    ConfigRecord, ConfigValue, MetricRecord, WireCodec, UNSUPPORTED_CODEC_ERR, WIRE_CODEC_KEY,
};
#[allow(deprecated)]
pub use crate::flower::records::{config_get_f64, config_get_i64, config_get_str};

// ---------------------------------------------------------------------------
// Codec limits (hoisted, named, tested)
// ---------------------------------------------------------------------------

/// First byte of every v2 frame. Legacy v1 frames start with a message
/// tag, which is never this value — that is the version discriminator.
pub const FRAME_MAGIC_V2: u8 = 0xF2;

/// Maximum entries in one config record.
pub const MAX_CONFIG_ENTRIES: usize = 4096;
/// Maximum entries in one metric record.
pub const MAX_METRIC_ENTRIES: usize = 4096;
/// Maximum task instructions in one `TaskInsList`.
pub const MAX_TASKS_PER_LIST: usize = 65536;
/// Maximum tensors in one array record.
pub const MAX_TENSORS_PER_RECORD: usize = 4096;
/// Maximum payload bytes of a single tensor (1 GiB, matching
/// `util::bytes::MAX_FIELD`).
pub const MAX_TENSOR_BYTES: usize = 1 << 30;
/// Maximum dimensions in a tensor shape.
pub const MAX_SHAPE_DIMS: usize = 16;
/// Largest node id a client may pin via `CreateNode { requested }`.
/// The SuperLink keeps its auto-assign counter ahead of pinned ids with
/// `fetch_max(requested + 1)`; an unbounded pin of `u64::MAX` would wrap
/// that counter to 0 and let the link hand out duplicate node ids, so
/// out-of-range pins are rejected at decode (the peer sees a
/// [`FlowerMsg::Error`] reply, never a wrapped counter).
pub const MAX_PINNED_NODE_ID: u64 = (1 << 48) - 1;

fn check_pinned_node_id(requested: u64) -> Result<u64, WireError> {
    if requested > MAX_PINNED_NODE_ID {
        return Err(WireError::Malformed("pinned node id out of range"));
    }
    Ok(requested)
}

pub(crate) fn write_config(w: &mut Writer, c: &ConfigRecord) {
    w.u32(c.len() as u32);
    for (k, v) in c {
        w.str(k);
        match v {
            ConfigValue::F64(x) => {
                w.u8(0);
                w.f64(*x);
            }
            ConfigValue::I64(x) => {
                w.u8(1);
                w.u64(*x as u64);
            }
            ConfigValue::Str(s) => {
                w.u8(2);
                w.str(s);
            }
            ConfigValue::Bool(b) => {
                w.u8(3);
                w.u8(*b as u8);
            }
        }
    }
}

pub(crate) fn read_config(r: &mut FrameReader) -> Result<ConfigRecord, WireError> {
    let n = r.u32()? as usize;
    if n > MAX_CONFIG_ENTRIES {
        return Err(WireError::TooLong {
            len: n,
            limit: MAX_CONFIG_ENTRIES,
        });
    }
    let mut c = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?;
        let v = match r.u8()? {
            0 => ConfigValue::F64(r.f64()?),
            1 => ConfigValue::I64(r.u64()? as i64),
            2 => ConfigValue::Str(r.str()?),
            3 => ConfigValue::Bool(r.u8()? != 0),
            t => return Err(WireError::BadTag(t)),
        };
        c.push((k, v));
    }
    // from_pairs preserves entries verbatim (duplicate keys included),
    // so decode -> encode is byte-exact even for hostile frames.
    Ok(ConfigRecord::from_pairs(c))
}

pub(crate) fn write_metrics(w: &mut Writer, m: &MetricRecord) {
    w.u32(m.len() as u32);
    for (k, v) in m {
        w.str(k);
        w.f64(*v);
    }
}

pub(crate) fn read_metrics(r: &mut FrameReader) -> Result<MetricRecord, WireError> {
    let n = r.u32()? as usize;
    if n > MAX_METRIC_ENTRIES {
        return Err(WireError::TooLong {
            len: n,
            limit: MAX_METRIC_ENTRIES,
        });
    }
    let mut m = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?;
        let v = r.f64()?;
        m.push((k, v));
    }
    Ok(MetricRecord::from_pairs(m))
}

// ---------------------------------------------------------------------------
// ArrayRecord segments
// ---------------------------------------------------------------------------

/// Encode a record as length-prefixed tensor segments. The payload copy
/// here (record buffer -> frame buffer) is the single unavoidable
/// serialization copy of the send path.
///
/// Asserts the same limits the decoder enforces, so an oversized record
/// fails loudly at the sender (like the old `Writer::f32s` size assert)
/// instead of as a confusing remote `WireError` at the peer.
pub(crate) fn write_record(w: &mut Writer, rec: &ArrayRecord) {
    assert!(
        rec.len() <= MAX_TENSORS_PER_RECORD,
        "record has {} tensors, wire limit is {MAX_TENSORS_PER_RECORD}",
        rec.len()
    );
    w.u32(rec.len() as u32);
    for t in rec.tensors() {
        assert!(
            t.shape().len() <= MAX_SHAPE_DIMS,
            "tensor '{}' has {} dims, wire limit is {MAX_SHAPE_DIMS}",
            t.name(),
            t.shape().len()
        );
        assert!(
            t.byte_len() <= MAX_TENSOR_BYTES,
            "tensor '{}' is {} bytes, wire limit is {MAX_TENSOR_BYTES}",
            t.name(),
            t.byte_len()
        );
        w.str(t.name());
        w.u8(t.dtype().wire_tag());
        // Codec tag + per-codec parameters, alongside the dtype tag.
        let enc = t.encoding();
        w.u8(enc.wire_tag());
        match enc {
            Encoding::Dense | Encoding::F16 | Encoding::BF16 => {}
            Encoding::Int8 { scale, zero_point } => {
                w.f32(scale);
                w.f32(zero_point);
            }
            Encoding::TopK { k } => w.u32(k),
            Encoding::TopKInt8 {
                k,
                scale,
                zero_point,
            } => {
                w.u32(k);
                w.f32(scale);
                w.f32(zero_point);
            }
            Encoding::DeltaXor { base_version } => w.u64(base_version),
        }
        w.u32(t.shape().len() as u32);
        for d in t.shape() {
            assert!(
                *d <= u32::MAX as usize,
                "tensor '{}' dim {d} exceeds the u32 wire format",
                t.name()
            );
            w.u32(*d as u32);
        }
        w.u64(t.byte_len() as u64);
        crate::telemetry::bump("records.encode_bytes_copied", t.byte_len() as i64);
        w.raw(t.data().as_slice());
    }
}

/// Decode a record zero-copy: every tensor's payload is a shared view
/// into the frame buffer the reader wraps.
pub(crate) fn read_record(r: &mut FrameReader) -> Result<ArrayRecord, WireError> {
    let n = r.u32()? as usize;
    if n > MAX_TENSORS_PER_RECORD {
        return Err(WireError::TooLong {
            len: n,
            limit: MAX_TENSORS_PER_RECORD,
        });
    }
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str()?;
        let dtype = DType::from_wire_tag(r.u8()?)?;
        // Codec tag + per-codec parameters. An unknown tag (a newer
        // peer's codec) surfaces as `BadTag` — callers on the result
        // path convert it into a typed per-node refusal.
        let enc = match r.u8()? {
            0 => Encoding::Dense,
            1 => Encoding::F16,
            2 => Encoding::BF16,
            3 => Encoding::Int8 {
                scale: r.f32()?,
                zero_point: r.f32()?,
            },
            4 => Encoding::TopK { k: r.u32()? },
            5 => Encoding::TopKInt8 {
                k: r.u32()?,
                scale: r.f32()?,
                zero_point: r.f32()?,
            },
            6 => Encoding::DeltaXor {
                base_version: r.u64()?,
            },
            t => return Err(WireError::BadTag(t)),
        };
        let ndim = r.u32()? as usize;
        if ndim > MAX_SHAPE_DIMS {
            return Err(WireError::TooLong {
                len: ndim,
                limit: MAX_SHAPE_DIMS,
            });
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut elems: u64 = 1;
        for _ in 0..ndim {
            let d = r.u32()? as usize;
            elems = elems.saturating_mul(d as u64);
            shape.push(d);
        }
        let byte_len = r.u64()?;
        // Bound BEFORE any narrowing: a wire-supplied u64 length must
        // never truncate into a smaller platform usize or size an
        // allocation (satellite: unchecked-length-cast audit).
        if byte_len > MAX_TENSOR_BYTES as u64 {
            return Err(WireError::TooLong {
                len: usize::try_from(byte_len).unwrap_or(usize::MAX),
                limit: MAX_TENSOR_BYTES,
            });
        }
        // Exact per-encoding length in u64 math (a hostile `k` cannot
        // overflow), validated against the declared byte length.
        let want = enc.encoded_byte_len(dtype, elems);
        if want != byte_len {
            return Err(WireError::Malformed(
                "tensor byte length != encoding * shape",
            ));
        }
        let data = r.take_shared(byte_len as usize)?;
        let tensor = Tensor::new_encoded(name, dtype, shape, enc, data)
            .map_err(|_| WireError::Malformed("invalid tensor segment"))?;
        tensors.push(tensor);
    }
    ArrayRecord::from_tensors(tensors).map_err(|_| WireError::Malformed("duplicate tensor name"))
}

/// The type of a [`Message`]: what the receiving node should DO with
/// its content. `Train`/`Evaluate` are the classic FL verbs (the only
/// two the pre-redesign stack could express); `Query` is the federated
/// analytics verb (compute over local data, no model anywhere); and
/// `Custom(name)` opens the scenario axis — any workload a registered
/// handler understands, flowing through every layer (wire, SuperNode
/// dispatch, mods, bridge) without those layers changing.
///
/// # Examples
///
/// ```
/// use flarelink::flower::message::MessageType;
///
/// let t = MessageType::Custom("personalize".into());
/// assert_eq!(t.name(), "personalize");
/// assert_eq!(MessageType::Query.name(), "query");
/// // v1 peers predate Query/Custom: only Train/Evaluate survive a
/// // legacy round-trip.
/// assert!(MessageType::Train.rides_v1());
/// assert!(!t.rides_v1());
/// ```
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub enum MessageType {
    /// Local training over the carried parameters (legacy `Fit`).
    #[default]
    Train,
    /// Local evaluation of the carried parameters.
    Evaluate,
    /// Federated analytics: answer from local data; no model involved.
    Query,
    /// App-defined verb, dispatched by name to a registered handler.
    Custom(String),
}

impl MessageType {
    /// Stable lower-case name (the `Custom` payload is the name itself).
    pub fn name(&self) -> &str {
        match self {
            MessageType::Train => "train",
            MessageType::Evaluate => "evaluate",
            MessageType::Query => "query",
            MessageType::Custom(name) => name,
        }
    }

    /// Construct a custom type by name.
    pub fn custom(name: impl Into<String>) -> MessageType {
        MessageType::Custom(name.into())
    }

    /// Can a legacy v1 frame represent this type? (v1 predates the
    /// generic Message API: its tag byte only distinguishes fit and
    /// evaluate.)
    pub fn rides_v1(&self) -> bool {
        matches!(self, MessageType::Train | MessageType::Evaluate)
    }

    fn wire_tag(&self) -> u8 {
        match self {
            MessageType::Train => 0,
            MessageType::Evaluate => 1,
            MessageType::Query => 2,
            MessageType::Custom(_) => 3,
        }
    }
}

pub(crate) fn write_message_type(w: &mut Writer, t: &MessageType) {
    w.u8(t.wire_tag());
    if let MessageType::Custom(name) = t {
        w.str(name);
    }
}

pub(crate) fn read_message_type(r: &mut FrameReader) -> Result<MessageType, WireError> {
    Ok(match r.u8()? {
        0 => MessageType::Train,
        1 => MessageType::Evaluate,
        2 => MessageType::Query,
        3 => MessageType::Custom(r.str()?),
        t => return Err(WireError::BadTag(t)),
    })
}

/// Server -> client task instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskIns {
    pub task_id: u64,
    pub run_id: u64,
    /// Round number (Flower's group_id).
    pub round: u64,
    /// What the receiving node should do with the content (new v2 wire
    /// field; the slot that used to be the fit/evaluate tag byte — v1
    /// frames decode to `Train`/`Evaluate` only).
    pub message_type: MessageType,
    /// Delivery attempt: 0 for the original assignment, incremented each
    /// time the SuperLink redelivers the task to another node after its
    /// assignee's liveness lease expired (bounded by the link's
    /// `max_redeliveries`).
    pub attempt: u32,
    /// May the SuperLink reassign this task to a DIFFERENT node if its
    /// assignee dies? FL fit/evaluate tasks are node-affine (each node
    /// trains/evaluates on its own data) so the ServerApp sets `false` —
    /// a substitute's result would pollute the cohort; node-agnostic
    /// workloads opt in.
    pub redeliver: bool,
    /// Global model version this task's parameters were cut from. The
    /// synchronous round path leaves it 0; the asynchronous driver tags
    /// every dispatch so result staleness (`current_version - this`) is
    /// computable when the result finally lands. v1 frames cannot carry
    /// it (decodes as 0) — the SuperLink records the version per task at
    /// push time and stamps it back onto results authoritatively.
    pub model_version: u64,
    /// Global model parameters (named, dtyped tensors).
    pub parameters: ArrayRecord,
    pub config: ConfigRecord,
}

impl TaskIns {
    /// The instruction's payload as a full record bundle.
    pub fn record(&self) -> RecordDict {
        RecordDict {
            arrays: self.parameters.clone(),
            metrics: crate::flower::records::MetricRecord::new(),
            configs: self.config.clone(),
        }
    }
}

/// Client -> server task result.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskRes {
    pub task_id: u64,
    pub run_id: u64,
    pub node_id: u64,
    /// Empty string = success; else the client-side error.
    pub error: String,
    /// Echo of the instruction's message type (new v2 wire field; v1
    /// replies cannot carry it and decode as `Train`, the legacy
    /// default — legacy drivers never read it).
    pub message_type: MessageType,
    /// Updated parameters (fit) or empty (evaluate).
    pub parameters: ArrayRecord,
    pub num_examples: u64,
    /// loss for evaluate tasks; 0 for fit unless reported in metrics.
    pub loss: f64,
    pub metrics: MetricRecord,
    /// Reply-side config channel (new v2 wire field; v1 decodes empty):
    /// a handler's `Message` reply carries its `content.configs` here,
    /// so query/custom workloads can return structured non-tensor
    /// answers. Fit/evaluate replies leave it empty (bit-identical to
    /// the pre-redesign frames).
    pub configs: ConfigRecord,
    /// Echo of the instruction's `model_version`: the global model
    /// version this result was computed from (0 on the sync path and in
    /// legacy v1 frames; the SuperLink overrides it with its own
    /// per-task record, so a stale or legacy client cannot misreport
    /// staleness).
    pub model_version: u64,
}

impl TaskRes {
    /// The result's payload as a full record bundle.
    pub fn record(&self) -> RecordDict {
        RecordDict {
            arrays: self.parameters.clone(),
            metrics: self.metrics.clone(),
            configs: self.configs.clone(),
        }
    }
}

// ---------------------------------------------------------------------------
// Message: the generic app-boundary view
// ---------------------------------------------------------------------------

/// Delivery/identity metadata of one [`Message`] (Flower's `Metadata`).
/// Instructions flow server -> node with `dst_node_id` set; replies flow
/// back with `src_node_id` set (and `num_examples`/`loss` carrying the
/// reply's scalar stats — the weight channel every aggregation honours).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metadata {
    pub run_id: u64,
    /// The task id on the wire: assigned by the SuperLink at push time.
    pub message_id: u64,
    /// Node that produced this message (0 = the server/driver).
    pub src_node_id: u64,
    /// Node this message is addressed to (0 = the server/driver).
    pub dst_node_id: u64,
    /// Round / commit number (Flower's group_id).
    pub round: u64,
    /// Delivery attempt (see [`TaskIns::attempt`]).
    pub attempt: u32,
    /// May the SuperLink reassign to another node on lease expiry?
    pub redeliver: bool,
    /// Global model version the content was cut from (async mode).
    pub model_version: u64,
    /// Reply stat: examples behind this result (0 on instructions).
    pub num_examples: u64,
    /// Reply stat: evaluation loss (0.0 on instructions and fit replies).
    pub loss: f64,
}

/// The generic message the app boundary speaks (Flower's `Message`):
/// a [`MessageType`] verb, a [`RecordDict`] content bundle, and
/// [`Metadata`]. Everything a SuperNode executes and everything a
/// driver pushes or pulls is one of these — fit/evaluate, analytics
/// queries, and custom workloads all ride the same shape, which is why
/// new scenarios need no wire/dispatch changes.
///
/// On the wire a `Message` is carried by [`TaskIns`] (instruction
/// direction) or [`TaskRes`] (reply direction); the conversions below
/// are total and bit-preserving (content tensors are refcounted views —
/// no payload copies).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Message {
    pub message_type: MessageType,
    pub content: RecordDict,
    pub metadata: Metadata,
    /// Client-side error (reply direction; empty = success).
    pub error: String,
}

impl Message {
    /// A fresh instruction of `message_type` addressed to `dst_node_id`.
    pub fn new(message_type: MessageType, dst_node_id: u64, content: RecordDict) -> Message {
        Message {
            message_type,
            content,
            metadata: Metadata {
                dst_node_id,
                ..Metadata::default()
            },
            error: String::new(),
        }
    }

    /// A `Train` instruction carrying parameters + config (the classic
    /// fit push).
    pub fn train(dst_node_id: u64, parameters: ArrayRecord, config: ConfigRecord) -> Message {
        Message::new(
            MessageType::Train,
            dst_node_id,
            RecordDict {
                arrays: parameters,
                metrics: MetricRecord::new(),
                configs: config,
            },
        )
    }

    /// An `Evaluate` instruction carrying parameters + config.
    pub fn evaluate(dst_node_id: u64, parameters: ArrayRecord, config: ConfigRecord) -> Message {
        let mut m = Message::train(dst_node_id, parameters, config);
        m.message_type = MessageType::Evaluate;
        m
    }

    /// A `Query` instruction: config only — **no model parameters
    /// anywhere** (the federated-analytics path).
    pub fn query(dst_node_id: u64, config: ConfigRecord) -> Message {
        Message::new(
            MessageType::Query,
            dst_node_id,
            RecordDict::from_configs(config),
        )
    }

    /// Builder: set run/round identity on an instruction.
    pub fn for_round(mut self, run_id: u64, round: u64) -> Message {
        self.metadata.run_id = run_id;
        self.metadata.round = round;
        self
    }

    /// Builder: tag the global model version (async driver).
    pub fn with_model_version(mut self, version: u64) -> Message {
        self.metadata.model_version = version;
        self
    }

    /// Build the success reply to this instruction: same type and
    /// identity, src/dst swapped.
    pub fn reply(&self, content: RecordDict) -> Message {
        Message {
            message_type: self.message_type.clone(),
            content,
            metadata: Metadata {
                src_node_id: self.metadata.dst_node_id,
                dst_node_id: self.metadata.src_node_id,
                num_examples: 0,
                loss: 0.0,
                ..self.metadata.clone()
            },
            error: String::new(),
        }
    }

    /// Build the error reply to this instruction (empty content).
    pub fn reply_err(&self, error: impl Into<String>) -> Message {
        let mut m = self.reply(RecordDict::default());
        m.error = error.into();
        m
    }

    /// Builder: reply stat — examples behind this result.
    pub fn with_examples(mut self, num_examples: u64) -> Message {
        self.metadata.num_examples = num_examples;
        self
    }

    /// Builder: reply stat — evaluation loss.
    pub fn with_loss(mut self, loss: f64) -> Message {
        self.metadata.loss = loss;
        self
    }

    /// Did this (reply) message succeed?
    pub fn is_ok(&self) -> bool {
        self.error.is_empty()
    }

    /// Instruction view of a received [`TaskIns`] (node side). The
    /// receiving node fills `metadata.dst_node_id` with its own id.
    pub fn from_ins(ins: TaskIns, dst_node_id: u64) -> Message {
        Message {
            message_type: ins.message_type,
            content: RecordDict {
                arrays: ins.parameters,
                metrics: MetricRecord::new(),
                configs: ins.config,
            },
            metadata: Metadata {
                run_id: ins.run_id,
                message_id: ins.task_id,
                src_node_id: 0,
                dst_node_id,
                round: ins.round,
                attempt: ins.attempt,
                redeliver: ins.redeliver,
                model_version: ins.model_version,
                num_examples: 0,
                loss: 0.0,
            },
            error: String::new(),
        }
    }

    /// Wire form of an instruction (driver side). Instruction metrics
    /// have no wire slot (nothing consumes them — Flower's TaskIns
    /// doesn't carry metrics either); they are dropped here.
    pub fn into_ins(self) -> TaskIns {
        TaskIns {
            task_id: self.metadata.message_id,
            run_id: self.metadata.run_id,
            round: self.metadata.round,
            message_type: self.message_type,
            attempt: self.metadata.attempt,
            redeliver: self.metadata.redeliver,
            model_version: self.metadata.model_version,
            parameters: self.content.arrays,
            config: self.content.configs,
        }
    }

    /// Reply view of a received [`TaskRes`] (driver side).
    pub fn from_res(res: TaskRes) -> Message {
        Message {
            message_type: res.message_type,
            content: RecordDict {
                arrays: res.parameters,
                metrics: res.metrics,
                configs: res.configs,
            },
            metadata: Metadata {
                run_id: res.run_id,
                message_id: res.task_id,
                src_node_id: res.node_id,
                dst_node_id: 0,
                round: 0,
                attempt: 0,
                redeliver: false,
                model_version: res.model_version,
                num_examples: res.num_examples,
                loss: res.loss,
            },
            error: res.error,
        }
    }

    /// Wire form of a reply (node side).
    pub fn into_res(self) -> TaskRes {
        TaskRes {
            task_id: self.metadata.message_id,
            run_id: self.metadata.run_id,
            node_id: self.metadata.src_node_id,
            error: self.error,
            message_type: self.message_type,
            parameters: self.content.arrays,
            num_examples: self.metadata.num_examples,
            loss: self.metadata.loss,
            metrics: self.content.metrics,
            configs: self.content.configs,
            model_version: self.metadata.model_version,
        }
    }
}

/// All SuperNode<->SuperLink frames.
#[derive(Clone, Debug, PartialEq)]
pub enum FlowerMsg {
    // client -> server
    /// Register a node. `requested` pins a stable node id (partition
    /// index) so the client<->node binding is deterministic across runs
    /// and transports (the Fig. 5 requirement); 0 = server-assigned.
    CreateNode { requested: u64 },
    /// Pull pending instructions for this node.
    PullTaskIns { node_id: u64 },
    PushTaskRes { res: TaskRes },
    DeleteNode { node_id: u64 },
    /// Enter push-mode delivery: the serving layer starts PUSHING
    /// `TaskInsList` frames down this stream whenever tasks queue for
    /// the node, instead of the node polling `PullTaskIns` every few
    /// ms. Sent once per task stream; the immediate reply is the
    /// current backlog (possibly empty).
    Subscribe { node_id: u64 },
    // server -> client
    NodeCreated { node_id: u64 },
    /// Zero or more instructions + whether any run is still active.
    TaskInsList { tasks: Vec<TaskIns>, active: bool },
    PushAccepted,
    NodeDeleted,
    /// Server-side error string.
    Error { message: String },
}

impl FlowerMsg {
    /// Encode as a v2 record frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        match self {
            FlowerMsg::CreateNode { requested } => {
                w.u8(0);
                w.u64(*requested);
            }
            FlowerMsg::PullTaskIns { node_id } => {
                w.u8(1);
                w.u64(*node_id);
            }
            FlowerMsg::PushTaskRes { res } => {
                w.u8(2);
                w.u64(res.task_id);
                w.u64(res.run_id);
                w.u64(res.node_id);
                w.str(&res.error);
                write_message_type(&mut w, &res.message_type);
                write_record(&mut w, &res.parameters);
                w.u64(res.num_examples);
                w.f64(res.loss);
                write_metrics(&mut w, &res.metrics);
                write_config(&mut w, &res.configs);
                w.u64(res.model_version);
            }
            FlowerMsg::DeleteNode { node_id } => {
                w.u8(3);
                w.u64(*node_id);
            }
            FlowerMsg::Subscribe { node_id } => {
                w.u8(4);
                w.u64(*node_id);
            }
            FlowerMsg::NodeCreated { node_id } => {
                w.u8(16);
                w.u64(*node_id);
            }
            FlowerMsg::TaskInsList { tasks, active } => {
                w.u8(17);
                w.u8(*active as u8);
                w.u32(tasks.len() as u32);
                for t in tasks {
                    w.u64(t.task_id);
                    w.u64(t.run_id);
                    w.u64(t.round);
                    write_message_type(&mut w, &t.message_type);
                    w.u32(t.attempt);
                    w.u8(t.redeliver as u8);
                    write_record(&mut w, &t.parameters);
                    write_config(&mut w, &t.config);
                    w.u64(t.model_version);
                }
            }
            FlowerMsg::PushAccepted => w.u8(18),
            FlowerMsg::NodeDeleted => w.u8(19),
            FlowerMsg::Error { message } => {
                w.u8(20);
                w.str(message);
            }
        }
        w.into_bytes()
    }

    /// Encode as a legacy v1 frame (flat f32 parameters). Lossy for
    /// records that are not a single flat f32 tensor — interop path for
    /// peers that predate the record codec, and the test vector for the
    /// legacy decode path. Also lossy for message types: v1's tag byte
    /// only distinguishes fit and evaluate, so `Query`/`Custom`
    /// instructions fall back to the `Train` tag (callers must not
    /// route non-FL messages to v1 peers — check
    /// [`MessageType::rides_v1`] first).
    pub fn encode_v1(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            FlowerMsg::CreateNode { requested } => {
                w.u8(0);
                w.u64(*requested);
            }
            FlowerMsg::PullTaskIns { node_id } => {
                w.u8(1);
                w.u64(*node_id);
            }
            FlowerMsg::PushTaskRes { res } => {
                w.u8(2);
                w.u64(res.task_id);
                w.u64(res.run_id);
                w.u64(res.node_id);
                w.str(&res.error);
                w.f32s(&res.parameters.to_flat());
                w.u64(res.num_examples);
                w.f64(res.loss);
                write_metrics(&mut w, &res.metrics);
            }
            FlowerMsg::DeleteNode { node_id } => {
                w.u8(3);
                w.u64(*node_id);
            }
            FlowerMsg::Subscribe { node_id } => {
                w.u8(4);
                w.u64(*node_id);
            }
            FlowerMsg::NodeCreated { node_id } => {
                w.u8(16);
                w.u64(*node_id);
            }
            FlowerMsg::TaskInsList { tasks, active } => {
                w.u8(17);
                w.u8(*active as u8);
                w.u32(tasks.len() as u32);
                for t in tasks {
                    w.u64(t.task_id);
                    w.u64(t.run_id);
                    w.u64(t.round);
                    // v1 tag byte: evaluate stays 1; everything else —
                    // including Query/Custom, which v1 cannot express —
                    // collapses to the fit tag 0.
                    w.u8(matches!(t.message_type, MessageType::Evaluate) as u8);
                    w.f32s(&t.parameters.to_flat());
                    write_config(&mut w, &t.config);
                }
            }
            FlowerMsg::PushAccepted => w.u8(18),
            FlowerMsg::NodeDeleted => w.u8(19),
            FlowerMsg::Error { message } => {
                w.u8(20);
                w.str(message);
            }
        }
        w.into_bytes()
    }

    /// Decode from a borrowed buffer. Copies the buffer once to obtain
    /// shared ownership; zero-copy callers that own the frame should use
    /// [`FlowerMsg::decode_shared`] instead.
    pub fn decode(buf: &[u8]) -> Result<FlowerMsg, WireError> {
        Self::decode_shared(Bytes::copy_from_slice(buf))
    }

    /// Decode an owned frame. For v2 frames every tensor payload in the
    /// result is a zero-copy view into `frame`'s allocation.
    pub fn decode_shared(frame: Bytes) -> Result<FlowerMsg, WireError> {
        match frame.as_slice().first() {
            None => Err(WireError::Truncated { at: 0, needed: 1 }),
            Some(&FRAME_MAGIC_V2) => Self::decode_v2(frame),
            Some(_) => Self::decode_v1(frame.as_slice()),
        }
    }

    fn decode_v2(frame: Bytes) -> Result<FlowerMsg, WireError> {
        let mut r = FrameReader::new(frame);
        let magic = r.u8()?;
        debug_assert_eq!(magic, FRAME_MAGIC_V2);
        let tag = r.u8()?;
        let msg = match tag {
            0 => FlowerMsg::CreateNode {
                requested: check_pinned_node_id(r.u64()?)?,
            },
            1 => FlowerMsg::PullTaskIns { node_id: r.u64()? },
            2 => {
                let task_id = r.u64()?;
                let run_id = r.u64()?;
                let node_id = r.u64()?;
                let error = r.str()?;
                let message_type = read_message_type(&mut r)?;
                match read_record(&mut r) {
                    Ok(parameters) => FlowerMsg::PushTaskRes {
                        res: TaskRes {
                            task_id,
                            run_id,
                            node_id,
                            error,
                            message_type,
                            parameters,
                            num_examples: r.u64()?,
                            loss: r.f64()?,
                            metrics: read_metrics(&mut r)?,
                            configs: read_config(&mut r)?,
                            model_version: r.u64()?,
                        },
                    },
                    // An unknown codec/dtype tag from a newer peer: the
                    // result header already named its task/run/node, so
                    // surface a typed PER-NODE refusal the SuperLink
                    // stores like any failed result (mirrors
                    // `UNHANDLED_MESSAGE_ERR`) instead of erroring the
                    // whole frame or panicking.
                    Err(WireError::BadTag(t)) => {
                        crate::telemetry::bump("codec.unsupported_refusals", 1);
                        FlowerMsg::PushTaskRes {
                            res: TaskRes {
                                task_id,
                                run_id,
                                node_id,
                                error: format!(
                                    "{UNSUPPORTED_CODEC_ERR}: unknown wire tag {t} in result"
                                ),
                                message_type,
                                parameters: ArrayRecord::new(),
                                num_examples: 0,
                                loss: 0.0,
                                metrics: MetricRecord::new(),
                                configs: ConfigRecord::new(),
                                model_version: 0,
                            },
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
            3 => FlowerMsg::DeleteNode { node_id: r.u64()? },
            4 => FlowerMsg::Subscribe { node_id: r.u64()? },
            16 => FlowerMsg::NodeCreated { node_id: r.u64()? },
            17 => {
                let active = r.u8()? != 0;
                let n = r.u32()? as usize;
                if n > MAX_TASKS_PER_LIST {
                    return Err(WireError::TooLong {
                        len: n,
                        limit: MAX_TASKS_PER_LIST,
                    });
                }
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    let task_id = r.u64()?;
                    let run_id = r.u64()?;
                    let round = r.u64()?;
                    let message_type = read_message_type(&mut r)?;
                    let attempt = r.u32()?;
                    let redeliver = r.u8()? != 0;
                    let parameters = read_record(&mut r)?;
                    let config = read_config(&mut r)?;
                    let model_version = r.u64()?;
                    tasks.push(TaskIns {
                        task_id,
                        run_id,
                        round,
                        message_type,
                        attempt,
                        redeliver,
                        model_version,
                        parameters,
                        config,
                    });
                }
                FlowerMsg::TaskInsList { tasks, active }
            }
            18 => FlowerMsg::PushAccepted,
            19 => FlowerMsg::NodeDeleted,
            20 => FlowerMsg::Error { message: r.str()? },
            t => return Err(WireError::BadTag(t)),
        };
        Ok(msg)
    }

    /// Legacy v1 decode path: flat f32 parameter vectors become
    /// single-tensor records via [`ArrayRecord::from_flat`].
    fn decode_v1(buf: &[u8]) -> Result<FlowerMsg, WireError> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            0 => FlowerMsg::CreateNode {
                requested: check_pinned_node_id(r.u64()?)?,
            },
            1 => FlowerMsg::PullTaskIns { node_id: r.u64()? },
            2 => FlowerMsg::PushTaskRes {
                res: TaskRes {
                    task_id: r.u64()?,
                    run_id: r.u64()?,
                    node_id: r.u64()?,
                    error: r.str()?.to_string(),
                    // v1 predates the generic Message API: no type, no
                    // reply config channel on the wire.
                    message_type: MessageType::Train,
                    parameters: ArrayRecord::from_flat(&r.f32s()?),
                    num_examples: r.u64()?,
                    loss: r.f64()?,
                    metrics: read_metrics_v1(&mut r)?,
                    configs: ConfigRecord::new(),
                    // v1 predates async mode: version unknown — the
                    // SuperLink stamps its per-task record instead.
                    model_version: 0,
                },
            },
            3 => FlowerMsg::DeleteNode { node_id: r.u64()? },
            4 => FlowerMsg::Subscribe { node_id: r.u64()? },
            16 => FlowerMsg::NodeCreated { node_id: r.u64()? },
            17 => {
                let active = r.u8()? != 0;
                let n = r.u32()? as usize;
                if n > MAX_TASKS_PER_LIST {
                    return Err(WireError::TooLong {
                        len: n,
                        limit: MAX_TASKS_PER_LIST,
                    });
                }
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    let task_id = r.u64()?;
                    let run_id = r.u64()?;
                    let round = r.u64()?;
                    // v1 tag byte: only the two legacy FL verbs exist.
                    let message_type = match r.u8()? {
                        0 => MessageType::Train,
                        1 => MessageType::Evaluate,
                        t => return Err(WireError::BadTag(t)),
                    };
                    let parameters = ArrayRecord::from_flat(&r.f32s()?);
                    let config = read_config_v1(&mut r)?;
                    tasks.push(TaskIns {
                        task_id,
                        run_id,
                        round,
                        message_type,
                        // v1 predates redelivery: original, non-redeliverable.
                        attempt: 0,
                        redeliver: false,
                        // v1 predates async mode: version 0 (sync round).
                        model_version: 0,
                        parameters,
                        config,
                    });
                }
                FlowerMsg::TaskInsList { tasks, active }
            }
            18 => FlowerMsg::PushAccepted,
            19 => FlowerMsg::NodeDeleted,
            20 => FlowerMsg::Error {
                message: r.str()?.to_string(),
            },
            t => return Err(WireError::BadTag(t)),
        };
        Ok(msg)
    }
}

fn read_config_v1(r: &mut Reader) -> Result<ConfigRecord, WireError> {
    let n = r.u32()? as usize;
    if n > MAX_CONFIG_ENTRIES {
        return Err(WireError::TooLong {
            len: n,
            limit: MAX_CONFIG_ENTRIES,
        });
    }
    let mut c = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?.to_string();
        let v = match r.u8()? {
            0 => ConfigValue::F64(r.f64()?),
            1 => ConfigValue::I64(r.u64()? as i64),
            2 => ConfigValue::Str(r.str()?.to_string()),
            3 => ConfigValue::Bool(r.u8()? != 0),
            t => return Err(WireError::BadTag(t)),
        };
        c.push((k, v));
    }
    Ok(ConfigRecord::from_pairs(c))
}

fn read_metrics_v1(r: &mut Reader) -> Result<MetricRecord, WireError> {
    let n = r.u32()? as usize;
    if n > MAX_METRIC_ENTRIES {
        return Err(WireError::TooLong {
            len: n,
            limit: MAX_METRIC_ENTRIES,
        });
    }
    let mut m = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?.to_string();
        let v = r.f64()?;
        m.push((k, v));
    }
    Ok(MetricRecord::from_pairs(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::records::Tensor;

    fn mixed_record() -> ArrayRecord {
        ArrayRecord::from_tensors(vec![
            Tensor::from_f32("conv1.w", vec![2, 3], &[1.5, -2.0, 0.0, f32::NAN, -0.0, 1e-40]),
            Tensor::from_f64("head.bias", vec![2], &[0.25, -1e300]),
            Tensor::from_i64("steps", vec![1], &[-42]),
            Tensor::from_u8("quantized", vec![5], &[0, 1, 128, 254, 255]),
        ])
        .unwrap()
    }

    fn sample_ins() -> TaskIns {
        TaskIns {
            task_id: 9,
            run_id: 1,
            round: 3,
            message_type: MessageType::Train,
            attempt: 0,
            redeliver: false,
            // 0 so the same sample exercises the (lossy) v1 path too.
            model_version: 0,
            parameters: mixed_record(),
            config: ConfigRecord::from_pairs(vec![
                ("lr".into(), ConfigValue::F64(0.05)),
                ("epochs".into(), ConfigValue::I64(2)),
                ("mode".into(), ConfigValue::Str("iid".into())),
                ("prox".into(), ConfigValue::Bool(true)),
            ]),
        }
    }

    fn sample_res() -> TaskRes {
        TaskRes {
            task_id: 9,
            run_id: 1,
            node_id: 4,
            error: String::new(),
            message_type: MessageType::Train,
            parameters: ArrayRecord::from_flat(&[0.25; 10]),
            num_examples: 128,
            loss: 0.75,
            metrics: vec![("accuracy".to_string(), 0.9)].into(),
            configs: ConfigRecord::new(),
            model_version: 0,
        }
    }

    #[test]
    fn all_messages_roundtrip() {
        let msgs = vec![
            FlowerMsg::CreateNode { requested: 0 },
            FlowerMsg::CreateNode { requested: 3 },
            FlowerMsg::PullTaskIns { node_id: 7 },
            FlowerMsg::PushTaskRes { res: sample_res() },
            FlowerMsg::DeleteNode { node_id: 7 },
            FlowerMsg::Subscribe { node_id: 7 },
            FlowerMsg::NodeCreated { node_id: 7 },
            FlowerMsg::TaskInsList {
                tasks: vec![sample_ins()],
                active: true,
            },
            FlowerMsg::TaskInsList {
                tasks: vec![],
                active: false,
            },
            FlowerMsg::PushAccepted,
            FlowerMsg::NodeDeleted,
            FlowerMsg::Error {
                message: "no".into(),
            },
        ];
        for m in msgs {
            let buf = m.encode();
            assert_eq!(FlowerMsg::decode(&buf).unwrap(), m, "roundtrip {m:?}");
        }
    }

    #[test]
    fn mixed_dtype_record_roundtrips_bitexact() {
        let m = FlowerMsg::TaskInsList {
            tasks: vec![sample_ins()],
            active: true,
        };
        match FlowerMsg::decode(&m.encode()).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => {
                assert!(tasks[0].parameters.bits_equal(&mixed_record()));
                let t = tasks[0].parameters.get("conv1.w").unwrap();
                assert_eq!(t.dtype(), DType::F32);
                assert_eq!(t.shape(), &[2, 3]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decode_shared_is_zero_copy() {
        let m = FlowerMsg::PushTaskRes { res: sample_res() };
        let frame = Bytes::from_vec(m.encode());
        match FlowerMsg::decode_shared(frame.clone()).unwrap() {
            FlowerMsg::PushTaskRes { res } => {
                for t in res.parameters.tensors() {
                    assert!(
                        frame.shares_allocation(t.data()),
                        "tensor '{}' was copied out of the frame",
                        t.name()
                    );
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parameters_bitexact() {
        let mut ins = sample_ins();
        ins.parameters = ArrayRecord::from_flat(&[f32::NAN, -0.0, 1e-40, f32::MAX]);
        let m = FlowerMsg::TaskInsList {
            tasks: vec![ins.clone()],
            active: true,
        };
        match FlowerMsg::decode(&m.encode()).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => {
                assert!(tasks[0].parameters.bits_equal(&ins.parameters));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn legacy_v1_frames_still_decode() {
        // Flat-parameter messages written by the old codec decode into
        // single-tensor records with identical f32 bits.
        let flat = [f32::NAN, -0.0, 3.5, 1e-40];
        let res = TaskRes {
            parameters: ArrayRecord::from_flat(&flat),
            ..sample_res()
        };
        let msgs = vec![
            FlowerMsg::CreateNode { requested: 2 },
            FlowerMsg::PushTaskRes { res },
            FlowerMsg::TaskInsList {
                tasks: vec![TaskIns {
                    parameters: ArrayRecord::from_flat(&flat),
                    ..sample_ins()
                }],
                active: true,
            },
            FlowerMsg::Error {
                message: "legacy".into(),
            },
        ];
        for m in msgs {
            let v1 = m.encode_v1();
            assert_ne!(v1[0], FRAME_MAGIC_V2, "v1 frames must not carry the v2 magic");
            let back = FlowerMsg::decode(&v1).unwrap();
            // Compare via v2 re-encoding (NaN-safe byte comparison).
            assert_eq!(back.encode(), m.encode(), "legacy decode of {m:?}");
        }
    }

    #[test]
    fn attempt_count_roundtrips() {
        let ins = TaskIns {
            attempt: 3,
            redeliver: true,
            ..sample_ins()
        };
        let m = FlowerMsg::TaskInsList {
            tasks: vec![ins],
            active: true,
        };
        match FlowerMsg::decode(&m.encode()).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => {
                assert_eq!(tasks[0].attempt, 3);
                assert!(tasks[0].redeliver);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn model_version_roundtrips() {
        // Async-mode tagging: the version rides both directions on v2.
        let ins = TaskIns {
            model_version: 42,
            ..sample_ins()
        };
        let m = FlowerMsg::TaskInsList {
            tasks: vec![ins],
            active: true,
        };
        match FlowerMsg::decode(&m.encode()).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => assert_eq!(tasks[0].model_version, 42),
            other => panic!("{other:?}"),
        }
        let res = TaskRes {
            model_version: 17,
            ..sample_res()
        };
        match FlowerMsg::decode(&FlowerMsg::PushTaskRes { res }.encode()).unwrap() {
            FlowerMsg::PushTaskRes { res } => assert_eq!(res.model_version, 17),
            other => panic!("{other:?}"),
        }
        // Legacy v1 frames cannot carry the version: it decodes as 0.
        let ins_v1 = TaskIns {
            model_version: 9,
            parameters: ArrayRecord::from_flat(&[1.0]),
            ..sample_ins()
        };
        let v1 = FlowerMsg::TaskInsList {
            tasks: vec![ins_v1],
            active: true,
        }
        .encode_v1();
        match FlowerMsg::decode(&v1).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => assert_eq!(tasks[0].model_version, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn out_of_range_pinned_node_id_rejected() {
        for requested in [u64::MAX, MAX_PINNED_NODE_ID + 1] {
            let v2 = FlowerMsg::CreateNode { requested }.encode();
            assert!(
                matches!(FlowerMsg::decode(&v2), Err(WireError::Malformed(_))),
                "v2 pin {requested} must be rejected"
            );
            let v1 = FlowerMsg::CreateNode { requested }.encode_v1();
            assert!(
                matches!(FlowerMsg::decode(&v1), Err(WireError::Malformed(_))),
                "v1 pin {requested} must be rejected"
            );
        }
        // The boundary value still decodes.
        let ok = FlowerMsg::CreateNode {
            requested: MAX_PINNED_NODE_ID,
        };
        assert_eq!(FlowerMsg::decode(&ok.encode()).unwrap(), ok);
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(FlowerMsg::decode(&[99]).is_err());
        assert!(FlowerMsg::decode(&[]).is_err());
        assert!(FlowerMsg::decode(&[FRAME_MAGIC_V2]).is_err());
        assert!(FlowerMsg::decode(&[FRAME_MAGIC_V2, 99]).is_err());
    }

    #[test]
    fn config_accessors() {
        let c = sample_ins().config;
        assert_eq!(c.get_f64("lr"), Some(0.05));
        assert_eq!(c.get_f64("epochs"), Some(2.0));
        assert_eq!(c.get_i64("epochs"), Some(2));
        assert_eq!(c.get_str("mode"), Some("iid"));
        assert_eq!(c.get_f64("missing"), None);
    }

    #[test]
    fn query_and_custom_types_roundtrip_v2() {
        // The new scenario axis rides the wire: Query and Custom(name)
        // instructions (no parameters — zero model bytes) and replies
        // with the new configs channel round-trip byte-exactly on v2.
        for mt in [MessageType::Query, MessageType::custom("personalize")] {
            let ins = TaskIns {
                message_type: mt.clone(),
                parameters: ArrayRecord::new(),
                ..sample_ins()
            };
            let m = FlowerMsg::TaskInsList {
                tasks: vec![ins.clone()],
                active: true,
            };
            match FlowerMsg::decode(&m.encode()).unwrap() {
                FlowerMsg::TaskInsList { tasks, .. } => {
                    assert_eq!(tasks[0].message_type, mt);
                    assert!(tasks[0].parameters.is_empty(), "no model on the wire");
                }
                other => panic!("{other:?}"),
            }
            let res = TaskRes {
                message_type: mt.clone(),
                parameters: ArrayRecord::new(),
                configs: ConfigRecord::from_pairs(vec![(
                    "sketch_bins".to_string(),
                    ConfigValue::I64(32),
                )]),
                ..sample_res()
            };
            match FlowerMsg::decode(&FlowerMsg::PushTaskRes { res: res.clone() }.encode()).unwrap()
            {
                FlowerMsg::PushTaskRes { res: back } => {
                    assert_eq!(back, res);
                    assert_eq!(back.configs.get_i64("sketch_bins"), Some(32));
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn v1_decodes_message_types_to_train_evaluate_only() {
        // v1 frames predate the generic Message API: an Evaluate task
        // survives the legacy encoding, a Query falls back to Train
        // (the documented lossy mapping), and v1 replies decode with
        // Train + empty configs.
        assert!(!MessageType::Query.rides_v1());
        assert!(!MessageType::custom("x").rides_v1());
        let flat = ArrayRecord::from_flat(&[1.0]);
        for (sent, want) in [
            (MessageType::Train, MessageType::Train),
            (MessageType::Evaluate, MessageType::Evaluate),
            (MessageType::Query, MessageType::Train),
            (MessageType::custom("agg"), MessageType::Train),
        ] {
            let v1 = FlowerMsg::TaskInsList {
                tasks: vec![TaskIns {
                    message_type: sent,
                    parameters: flat.clone(),
                    ..sample_ins()
                }],
                active: true,
            }
            .encode_v1();
            match FlowerMsg::decode(&v1).unwrap() {
                FlowerMsg::TaskInsList { tasks, .. } => {
                    assert_eq!(tasks[0].message_type, want)
                }
                other => panic!("{other:?}"),
            }
        }
        let res = TaskRes {
            message_type: MessageType::Evaluate,
            parameters: flat,
            configs: ConfigRecord::from_pairs(vec![(
                "lost".to_string(),
                ConfigValue::Bool(true),
            )]),
            ..sample_res()
        };
        match FlowerMsg::decode(&FlowerMsg::PushTaskRes { res }.encode_v1()).unwrap() {
            FlowerMsg::PushTaskRes { res: back } => {
                assert_eq!(back.message_type, MessageType::Train, "v1 carries no type");
                assert!(back.configs.is_empty(), "v1 carries no reply configs");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn message_conversions_are_lossless_on_v2_fields() {
        // TaskIns -> Message -> TaskIns is identity.
        let ins = TaskIns {
            message_type: MessageType::Query,
            attempt: 2,
            redeliver: true,
            model_version: 5,
            parameters: ArrayRecord::new(),
            ..sample_ins()
        };
        let msg = Message::from_ins(ins.clone(), 7);
        assert_eq!(msg.metadata.dst_node_id, 7);
        assert_eq!(msg.metadata.message_id, ins.task_id);
        assert_eq!(msg.into_ins(), ins);
        // Reply swaps src/dst and keeps identity; Message -> TaskRes ->
        // Message preserves every field the wire carries.
        let ins_msg = Message::from_ins(ins, 7);
        let reply = ins_msg
            .reply(RecordDict::from_configs(ConfigRecord::from_pairs(vec![(
                "count".to_string(),
                ConfigValue::I64(3),
            )])))
            .with_examples(40)
            .with_loss(0.5);
        assert_eq!(reply.metadata.src_node_id, 7);
        assert_eq!(reply.metadata.dst_node_id, 0);
        assert_eq!(reply.metadata.message_id, ins_msg.metadata.message_id);
        assert!(reply.is_ok());
        let res = reply.clone().into_res();
        assert_eq!(res.node_id, 7);
        assert_eq!(res.num_examples, 40);
        assert_eq!(res.configs.get_i64("count"), Some(3));
        let back = Message::from_res(res);
        assert_eq!(back.message_type, MessageType::Query);
        assert_eq!(back.metadata.num_examples, 40);
        assert_eq!(back.metadata.loss, 0.5);
        assert_eq!(back.content, reply.content);
        // Error replies carry the error and empty content.
        let err = ins_msg.reply_err("boom");
        assert!(!err.is_ok());
        assert_eq!(err.clone().into_res().error, "boom");
    }

    #[test]
    fn truncated_rejected() {
        let buf = FlowerMsg::PushTaskRes { res: sample_res() }.encode();
        assert!(FlowerMsg::decode(&buf[..buf.len() - 3]).is_err());
        let ins = FlowerMsg::TaskInsList {
            tasks: vec![sample_ins()],
            active: true,
        }
        .encode();
        // Cut inside a tensor payload.
        assert!(FlowerMsg::decode(&ins[..ins.len() / 2]).is_err());
    }

    #[test]
    fn oversized_tensor_count_rejected() {
        // Hand-craft a PushTaskRes whose record claims too many tensors.
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2); // PushTaskRes
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str(""); // error
        w.u8(0); // message type: Train
        w.u32((MAX_TENSORS_PER_RECORD + 1) as u32);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    #[test]
    fn oversized_tensor_bytes_rejected() {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2); // PushTaskRes
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str("");
        w.u8(0); // message type: Train
        w.u32(1); // one tensor
        w.str("t");
        w.u8(DType::U8.wire_tag());
        w.u8(0); // codec: dense
        w.u32(1); // ndim
        w.u32(u32::MAX); // dim
        w.u64(MAX_TENSOR_BYTES as u64 + 1);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    #[test]
    fn inconsistent_tensor_length_rejected() {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2); // PushTaskRes
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str("");
        w.u8(0); // message type: Train
        w.u32(1);
        w.str("t");
        w.u8(DType::F32.wire_tag());
        w.u8(0); // codec: dense
        w.u32(1);
        w.u32(3); // 3 f32 elements -> needs 12 bytes
        w.u64(8); // but claims 8
        w.raw(&[0u8; 8]);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
    }

    #[test]
    fn oversized_config_rejected() {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(17); // TaskInsList
        w.u8(1); // active
        w.u32(1); // one task
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.u8(0); // message type: Train
        w.u32(0); // attempt
        w.u8(0); // redeliver
        w.u32(0); // empty record
        w.u32((MAX_CONFIG_ENTRIES + 1) as u32);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    #[test]
    fn oversized_task_list_rejected() {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(17);
        w.u8(1);
        w.u32((MAX_TASKS_PER_LIST + 1) as u32);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    // -- wire compression ---------------------------------------------------

    /// One tensor per codec, compressed from the same dense source.
    fn encoded_record() -> ArrayRecord {
        let vals: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.37).collect();
        let dense = Tensor::from_f32("w", vec![4, 4], &vals);
        let base = Tensor::from_f32("w", vec![4, 4], &vec![0.125f32; 16]);
        let mk = |name: &str, codec, base: Option<(&Tensor, u64)>| {
            let mut t = dense.compress(codec, base);
            t = Tensor::new_encoded(name, t.dtype(), t.shape().to_vec(), t.encoding(), {
                t.data().clone()
            })
            .unwrap();
            t
        };
        ArrayRecord::from_tensors(vec![
            dense.clone(),
            mk("w_f16", WireCodec::F16, None),
            mk("w_bf16", WireCodec::Bf16, None),
            mk("w_int8", WireCodec::Int8, None),
            mk("w_topk", WireCodec::TopK, None),
            mk("w_topk8", WireCodec::Int8TopK, None),
            mk("w_delta", WireCodec::Delta, Some((&base, 7))),
        ])
        .unwrap()
    }

    #[test]
    fn encoded_tensors_roundtrip_every_codec() {
        let rec = encoded_record();
        let res = TaskRes {
            parameters: rec.clone(),
            ..sample_res()
        };
        let frame = Bytes::from_vec(FlowerMsg::PushTaskRes { res }.encode());
        match FlowerMsg::decode_shared(frame.clone()).unwrap() {
            FlowerMsg::PushTaskRes { res: back } => {
                assert!(back.parameters.bits_equal(&rec), "codec tags + params survive");
                // Compressed payloads stay zero-copy views of the frame.
                for t in back.parameters.tensors() {
                    assert!(
                        frame.shares_allocation(t.data()),
                        "tensor '{}' was copied out of the frame",
                        t.name()
                    );
                }
                // The codec tag decoded, not just the bytes.
                assert_eq!(
                    back.parameters.get("w_f16").unwrap().encoding(),
                    Encoding::F16
                );
                assert!(matches!(
                    back.parameters.get("w_delta").unwrap().encoding(),
                    Encoding::DeltaXor { base_version: 7 }
                ));
            }
            other => panic!("{other:?}"),
        }
    }

    /// Craft a PushTaskRes frame up to (and including) a bad codec or
    /// dtype tag on its first tensor segment.
    fn res_frame_with_tags(dtype_tag: u8, codec_tag: u8) -> Vec<u8> {
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2); // PushTaskRes
        w.u64(11); // task_id
        w.u64(5); // run_id
        w.u64(44); // node_id
        w.str(""); // error
        w.u8(0); // message type: Train
        w.u32(1); // one tensor
        w.str("t");
        w.u8(dtype_tag);
        w.u8(codec_tag);
        w.into_bytes()
    }

    #[test]
    fn unknown_codec_tag_in_result_becomes_typed_per_node_refusal() {
        // A newer peer's codec must surface per-node (mirroring the
        // clientapp's UNHANDLED_MESSAGE_ERR), not kill the frame.
        for frame in [res_frame_with_tags(DType::F32.wire_tag(), 99), {
            // Unknown *dtype* tag takes the same refusal path.
            res_frame_with_tags(250, 0)
        }] {
            match FlowerMsg::decode(&frame).unwrap() {
                FlowerMsg::PushTaskRes { res } => {
                    assert!(
                        crate::flower::records::is_unsupported_codec(&res.error),
                        "typed marker, got {:?}",
                        res.error
                    );
                    assert_eq!(res.task_id, 11);
                    assert_eq!(res.run_id, 5);
                    assert_eq!(res.node_id, 44, "refusal keeps its node identity");
                    assert!(res.parameters.is_empty());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn unknown_codec_tag_in_instruction_is_a_frame_error() {
        // Instructions flow link -> node: there is no per-node failure
        // record to file, so a bad tag is a plain decode error.
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(17); // TaskInsList
        w.u8(1); // active
        w.u32(1); // one task
        w.u64(1); // task_id
        w.u64(1); // run_id
        w.u64(1); // round
        w.u8(0); // message type: Train
        w.u32(0); // attempt
        w.u8(0); // redeliver
        w.u64(0); // model_version
        w.u32(1); // one tensor
        w.str("t");
        w.u8(DType::F32.wire_tag());
        w.u8(99); // unknown codec
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::BadTag(99)), "{err:?}");
    }

    #[test]
    fn corrupt_codec_params_rejected_not_panicking() {
        // top-k claiming more kept entries than the tensor has elements.
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2);
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str("");
        w.u8(0);
        w.u32(1);
        w.str("t");
        w.u8(DType::F32.wire_tag());
        w.u8(4); // TopK
        w.u32(9); // k = 9 > 4 elems
        w.u32(1); // ndim
        w.u32(4); // dim
        w.u64(9 * 8); // consistent with k but not with elems
        w.raw(&[0u8; 72]);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");

        // int8 declared on a non-f32 tensor.
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2);
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str("");
        w.u8(0);
        w.u32(1);
        w.str("t");
        w.u8(DType::I64.wire_tag());
        w.u8(3); // Int8
        w.f32(1.0);
        w.f32(0.0);
        w.u32(1);
        w.u32(4);
        w.u64(4); // 4 quantized bytes
        w.raw(&[0u8; 4]);
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");

        // Oversized declared byte length must bound-check in u64 math
        // before any narrowing (never an attacker-sized allocation).
        let mut w = Writer::new();
        w.u8(FRAME_MAGIC_V2);
        w.u8(2);
        w.u64(1);
        w.u64(1);
        w.u64(1);
        w.str("");
        w.u8(0);
        w.u32(1);
        w.str("t");
        w.u8(DType::F32.wire_tag());
        w.u8(1); // F16
        w.u32(1);
        w.u32(u32::MAX);
        w.u64(u64::MAX - 3); // would truncate on a 32-bit cast
        let err = FlowerMsg::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    #[test]
    fn v1_frames_decode_with_identity_codec_defaults() {
        // Legacy peers predate codec tags entirely: every tensor a v1
        // frame produces is dense/identity.
        let res = TaskRes {
            parameters: ArrayRecord::from_flat(&[1.0, -2.5, 3.25]),
            ..sample_res()
        };
        let v1 = FlowerMsg::PushTaskRes { res }.encode_v1();
        match FlowerMsg::decode(&v1).unwrap() {
            FlowerMsg::PushTaskRes { res: back } => {
                for t in back.parameters.tensors() {
                    assert_eq!(t.encoding(), Encoding::Dense, "v1 implies identity codec");
                }
            }
            other => panic!("{other:?}"),
        }
    }
}
