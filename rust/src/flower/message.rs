//! Flower wire protocol: the frames exchanged between a SuperNode and
//! the SuperLink (paper §3.2). Mirrors Flower's TaskIns/TaskRes model:
//! clients *pull* task instructions and *push* task results.
//!
//! These bytes are what the FLARE bridge forwards opaquely (§4.2) — the
//! Fig. 5 bit-exactness claim rests on this codec being used identically
//! on the native and bridged paths.

use crate::util::bytes::{Reader, WireError, Writer};

/// Values carried in a task's config record (Flower's `Config` dict).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    F64(f64),
    I64(i64),
    Str(String),
    Bool(bool),
}

pub type ConfigRecord = Vec<(String, ConfigValue)>;

pub fn config_get_f64(c: &ConfigRecord, key: &str) -> Option<f64> {
    c.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
        ConfigValue::F64(x) => Some(*x),
        ConfigValue::I64(x) => Some(*x as f64),
        _ => None,
    })
}

pub fn config_get_i64(c: &ConfigRecord, key: &str) -> Option<i64> {
    c.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
        ConfigValue::I64(x) => Some(*x),
        _ => None,
    })
}

pub fn config_get_str<'a>(c: &'a ConfigRecord, key: &str) -> Option<&'a str> {
    c.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
        ConfigValue::Str(s) => Some(s.as_str()),
        _ => None,
    })
}

fn write_config(w: &mut Writer, c: &ConfigRecord) {
    w.u32(c.len() as u32);
    for (k, v) in c {
        w.str(k);
        match v {
            ConfigValue::F64(x) => {
                w.u8(0);
                w.f64(*x);
            }
            ConfigValue::I64(x) => {
                w.u8(1);
                w.u64(*x as u64);
            }
            ConfigValue::Str(s) => {
                w.u8(2);
                w.str(s);
            }
            ConfigValue::Bool(b) => {
                w.u8(3);
                w.u8(*b as u8);
            }
        }
    }
}

fn read_config(r: &mut Reader) -> Result<ConfigRecord, WireError> {
    let n = r.u32()? as usize;
    if n > 4096 {
        return Err(WireError::TooLong { len: n, limit: 4096 });
    }
    let mut c = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?.to_string();
        let v = match r.u8()? {
            0 => ConfigValue::F64(r.f64()?),
            1 => ConfigValue::I64(r.u64()? as i64),
            2 => ConfigValue::Str(r.str()?.to_string()),
            3 => ConfigValue::Bool(r.u8()? != 0),
            t => return Err(WireError::BadTag(t)),
        };
        c.push((k, v));
    }
    Ok(c)
}

/// Metric records are (name, f64) pairs (Flower's `Metrics`).
pub type MetricRecord = Vec<(String, f64)>;

fn write_metrics(w: &mut Writer, m: &MetricRecord) {
    w.u32(m.len() as u32);
    for (k, v) in m {
        w.str(k);
        w.f64(*v);
    }
}

fn read_metrics(r: &mut Reader) -> Result<MetricRecord, WireError> {
    let n = r.u32()? as usize;
    if n > 4096 {
        return Err(WireError::TooLong { len: n, limit: 4096 });
    }
    let mut m = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.str()?.to_string();
        m.push((k, r.f64()?));
    }
    Ok(m)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum TaskType {
    Fit = 0,
    Evaluate = 1,
}

/// Server -> client task instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskIns {
    pub task_id: u64,
    pub run_id: u64,
    /// Round number (Flower's group_id).
    pub round: u64,
    pub task_type: TaskType,
    /// Global model parameters (flat f32).
    pub parameters: Vec<f32>,
    pub config: ConfigRecord,
}

/// Client -> server task result.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskRes {
    pub task_id: u64,
    pub run_id: u64,
    pub node_id: u64,
    /// Empty string = success; else the client-side error.
    pub error: String,
    /// Updated parameters (fit) or empty (evaluate).
    pub parameters: Vec<f32>,
    pub num_examples: u64,
    /// loss for evaluate tasks; 0 for fit unless reported in metrics.
    pub loss: f64,
    pub metrics: MetricRecord,
}

/// All SuperNode<->SuperLink frames.
#[derive(Clone, Debug, PartialEq)]
pub enum FlowerMsg {
    // client -> server
    /// Register a node. `requested` pins a stable node id (partition
    /// index) so the client<->node binding is deterministic across runs
    /// and transports (the Fig. 5 requirement); 0 = server-assigned.
    CreateNode { requested: u64 },
    /// Pull pending instructions for this node.
    PullTaskIns { node_id: u64 },
    PushTaskRes { res: TaskRes },
    DeleteNode { node_id: u64 },
    // server -> client
    NodeCreated { node_id: u64 },
    /// Zero or more instructions + whether any run is still active.
    TaskInsList { tasks: Vec<TaskIns>, active: bool },
    PushAccepted,
    NodeDeleted,
    /// Server-side error string.
    Error { message: String },
}

impl FlowerMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            FlowerMsg::CreateNode { requested } => {
                w.u8(0);
                w.u64(*requested);
            }
            FlowerMsg::PullTaskIns { node_id } => {
                w.u8(1);
                w.u64(*node_id);
            }
            FlowerMsg::PushTaskRes { res } => {
                w.u8(2);
                w.u64(res.task_id);
                w.u64(res.run_id);
                w.u64(res.node_id);
                w.str(&res.error);
                w.f32s(&res.parameters);
                w.u64(res.num_examples);
                w.f64(res.loss);
                write_metrics(&mut w, &res.metrics);
            }
            FlowerMsg::DeleteNode { node_id } => {
                w.u8(3);
                w.u64(*node_id);
            }
            FlowerMsg::NodeCreated { node_id } => {
                w.u8(16);
                w.u64(*node_id);
            }
            FlowerMsg::TaskInsList { tasks, active } => {
                w.u8(17);
                w.u8(*active as u8);
                w.u32(tasks.len() as u32);
                for t in tasks {
                    w.u64(t.task_id);
                    w.u64(t.run_id);
                    w.u64(t.round);
                    w.u8(t.task_type as u8);
                    w.f32s(&t.parameters);
                    write_config(&mut w, &t.config);
                }
            }
            FlowerMsg::PushAccepted => w.u8(18),
            FlowerMsg::NodeDeleted => w.u8(19),
            FlowerMsg::Error { message } => {
                w.u8(20);
                w.str(message);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<FlowerMsg, WireError> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            0 => FlowerMsg::CreateNode { requested: r.u64()? },
            1 => FlowerMsg::PullTaskIns { node_id: r.u64()? },
            2 => FlowerMsg::PushTaskRes {
                res: TaskRes {
                    task_id: r.u64()?,
                    run_id: r.u64()?,
                    node_id: r.u64()?,
                    error: r.str()?.to_string(),
                    parameters: r.f32s()?,
                    num_examples: r.u64()?,
                    loss: r.f64()?,
                    metrics: read_metrics(&mut r)?,
                },
            },
            3 => FlowerMsg::DeleteNode { node_id: r.u64()? },
            16 => FlowerMsg::NodeCreated { node_id: r.u64()? },
            17 => {
                let active = r.u8()? != 0;
                let n = r.u32()? as usize;
                if n > 65536 {
                    return Err(WireError::TooLong { len: n, limit: 65536 });
                }
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    let task_id = r.u64()?;
                    let run_id = r.u64()?;
                    let round = r.u64()?;
                    let task_type = match r.u8()? {
                        0 => TaskType::Fit,
                        1 => TaskType::Evaluate,
                        t => return Err(WireError::BadTag(t)),
                    };
                    let parameters = r.f32s()?;
                    let config = read_config(&mut r)?;
                    tasks.push(TaskIns {
                        task_id,
                        run_id,
                        round,
                        task_type,
                        parameters,
                        config,
                    });
                }
                FlowerMsg::TaskInsList { tasks, active }
            }
            18 => FlowerMsg::PushAccepted,
            19 => FlowerMsg::NodeDeleted,
            20 => FlowerMsg::Error {
                message: r.str()?.to_string(),
            },
            t => return Err(WireError::BadTag(t)),
        };
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ins() -> TaskIns {
        TaskIns {
            task_id: 9,
            run_id: 1,
            round: 3,
            task_type: TaskType::Fit,
            parameters: vec![1.5, -2.0, 0.0],
            config: vec![
                ("lr".into(), ConfigValue::F64(0.05)),
                ("epochs".into(), ConfigValue::I64(2)),
                ("mode".into(), ConfigValue::Str("iid".into())),
                ("prox".into(), ConfigValue::Bool(true)),
            ],
        }
    }

    fn sample_res() -> TaskRes {
        TaskRes {
            task_id: 9,
            run_id: 1,
            node_id: 4,
            error: String::new(),
            parameters: vec![0.25; 10],
            num_examples: 128,
            loss: 0.75,
            metrics: vec![("accuracy".into(), 0.9)],
        }
    }

    #[test]
    fn all_messages_roundtrip() {
        let msgs = vec![
            FlowerMsg::CreateNode { requested: 0 },
            FlowerMsg::CreateNode { requested: 3 },
            FlowerMsg::PullTaskIns { node_id: 7 },
            FlowerMsg::PushTaskRes { res: sample_res() },
            FlowerMsg::DeleteNode { node_id: 7 },
            FlowerMsg::NodeCreated { node_id: 7 },
            FlowerMsg::TaskInsList {
                tasks: vec![sample_ins()],
                active: true,
            },
            FlowerMsg::TaskInsList {
                tasks: vec![],
                active: false,
            },
            FlowerMsg::PushAccepted,
            FlowerMsg::NodeDeleted,
            FlowerMsg::Error {
                message: "no".into(),
            },
        ];
        for m in msgs {
            let buf = m.encode();
            assert_eq!(FlowerMsg::decode(&buf).unwrap(), m, "roundtrip {m:?}");
        }
    }

    #[test]
    fn parameters_bitexact() {
        let mut ins = sample_ins();
        ins.parameters = vec![f32::NAN, -0.0, 1e-40, f32::MAX];
        let m = FlowerMsg::TaskInsList {
            tasks: vec![ins.clone()],
            active: true,
        };
        match FlowerMsg::decode(&m.encode()).unwrap() {
            FlowerMsg::TaskInsList { tasks, .. } => {
                for (a, b) in ins.parameters.iter().zip(tasks[0].parameters.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(FlowerMsg::decode(&[99]).is_err());
        assert!(FlowerMsg::decode(&[]).is_err());
    }

    #[test]
    fn config_accessors() {
        let c = sample_ins().config;
        assert_eq!(config_get_f64(&c, "lr"), Some(0.05));
        assert_eq!(config_get_f64(&c, "epochs"), Some(2.0));
        assert_eq!(config_get_i64(&c, "epochs"), Some(2));
        assert_eq!(config_get_str(&c, "mode"), Some("iid"));
        assert_eq!(config_get_f64(&c, "missing"), None);
    }

    #[test]
    fn truncated_rejected() {
        let buf = FlowerMsg::PushTaskRes { res: sample_res() }.encode();
        assert!(FlowerMsg::decode(&buf[..buf.len() - 3]).is_err());
    }
}
