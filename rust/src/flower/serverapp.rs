//! Flower ServerApp (paper Listing 1): drives FL rounds against the
//! SuperLink using a [`Strategy`]. Produces a [`History`] — the loss /
//! accuracy curves compared in Fig. 5 — and optionally streams round
//! metrics through FLARE experiment tracking (§5.2 hybrid mode).
//!
//! Fit results are **streamed**: each `TaskRes` is handed to the
//! strategy's incremental accumulator as it arrives
//! ([`SuperLink::for_each_result`]), so aggregation work overlaps
//! stragglers and the driver never buffers the whole cohort itself.
//! Each ServerApp drives ONE run (its `run_id`) and may share the
//! SuperLink — and its SuperNode fleet — with any number of concurrent
//! ServerApps; finishing this run leaves the others untouched.
//!
//! Determinism: client sampling uses a seeded PRNG keyed by (seed,
//! round); accumulators canonicalize by node id before any
//! order-sensitive float reduction. Two runs with the same seed —
//! regardless of transport (native or bridged) or result arrival order —
//! produce bit-identical histories, which is exactly the paper's
//! reproducibility experiment.
//!
//! Parameters are [`ArrayRecord`]s end to end: pushing a round's model
//! to N clients clones the record N times, which is N cheap reference
//! bumps on the shared tensor buffers — not N payload copies.

use std::sync::Arc;
use std::time::Duration;

use crate::flare::tracking::SummaryWriter;
use crate::flower::message::{ConfigValue, MetricRecord, TaskIns, TaskType};
use crate::flower::records::ArrayRecord;
use crate::flower::strategy::{EvalRes, FitRes, Strategy};
use crate::flower::superlink::SuperLink;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub num_rounds: u64,
    /// Fraction of connected nodes sampled for fit each round (1.0 = all).
    pub fraction_fit: f64,
    /// Fraction sampled for evaluate (0.0 disables federated evaluation).
    pub fraction_evaluate: f64,
    /// Wait for at least this many nodes before round 1.
    pub min_nodes: usize,
    pub round_timeout: Duration,
    /// Sampling seed — the "same random seeds" of the paper's Fig. 5.
    pub seed: u64,
    /// Fail the round if any sampled client errors (kept strict for
    /// reproducibility; Flower tolerates stragglers by default).
    pub accept_failures: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            num_rounds: 3,
            fraction_fit: 1.0,
            fraction_evaluate: 1.0,
            min_nodes: 2,
            round_timeout: Duration::from_secs(600),
            seed: 17,
            accept_failures: false,
        }
    }
}

/// One round's record in the history.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: u64,
    /// Example-weighted mean of client-reported fit metrics.
    pub fit_metrics: MetricRecord,
    /// Example-weighted federated evaluation loss (None if disabled).
    pub eval_loss: Option<f64>,
    pub eval_metrics: MetricRecord,
    /// Per-client evaluation (node_id, loss, metrics) — Fig. 6 series.
    pub per_client_eval: Vec<(u64, f64, MetricRecord)>,
}

/// The training curves of Fig. 5. `PartialEq` compares final parameters
/// byte-exactly (record equality is payload-bit equality), which IS the
/// bit-exact overlay check.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct History {
    pub rounds: Vec<RoundRecord>,
    /// Final global parameters.
    pub parameters: ArrayRecord,
}

impl History {
    /// CSV of the aggregated curves (round, fit metrics..., eval loss/metrics).
    pub fn to_csv(&self) -> String {
        let mut keys: Vec<String> = Vec::new();
        for r in &self.rounds {
            for (k, _) in r.fit_metrics.iter() {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
            for (k, _) in r.eval_metrics.iter() {
                let k = format!("eval_{k}");
                if !keys.contains(&k) {
                    keys.push(k);
                }
            }
        }
        let mut out = String::from("round,eval_loss");
        for k in &keys {
            out.push(',');
            out.push_str(k);
        }
        out.push('\n');
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{}",
                r.round,
                r.eval_loss.map(|l| l.to_string()).unwrap_or_default()
            ));
            for k in &keys {
                out.push(',');
                let v = if let Some(stripped) = k.strip_prefix("eval_") {
                    r.eval_metrics
                        .iter()
                        .find(|(mk, _)| mk == stripped)
                        .map(|(_, v)| *v)
                } else {
                    r.fit_metrics.iter().find(|(mk, _)| mk == k).map(|(_, v)| *v)
                };
                if let Some(v) = v {
                    out.push_str(&v.to_string());
                }
            }
            out.push('\n');
        }
        out
    }

    /// Bitwise equality of the final parameters (NaN-safe; kept for API
    /// clarity even though record `PartialEq` is already byte-exact).
    pub fn params_bits_equal(&self, other: &History) -> bool {
        self.parameters.bits_equal(&other.parameters)
    }
}

/// The ServerApp: strategy + config + initial parameters (paper
/// Listing 1: `ServerApp(config=ServerConfig(num_rounds=3), strategy=...)`).
pub struct ServerApp {
    pub strategy: Box<dyn Strategy>,
    pub config: ServerConfig,
    pub initial_parameters: ArrayRecord,
}

impl ServerApp {
    pub fn new(
        strategy: Box<dyn Strategy>,
        config: ServerConfig,
        initial_parameters: ArrayRecord,
    ) -> Self {
        Self {
            strategy,
            config,
            initial_parameters,
        }
    }

    /// Deterministic sample of `k` nodes for a round.
    fn sample(&self, nodes: &[u64], fraction: f64, round: u64) -> Vec<u64> {
        let k = ((nodes.len() as f64 * fraction).ceil() as usize)
            .clamp(1, nodes.len());
        let mut rng = Rng::new(self.config.seed).split(round);
        let mut idx = rng.sample_indices(nodes.len(), k);
        idx.sort_unstable(); // canonical order
        idx.into_iter().map(|i| nodes[i]).collect()
    }

    /// Run all rounds against the SuperLink. `tracker` streams round
    /// metrics via FLARE experiment tracking when present (§5.2).
    ///
    /// Opens run `run_id` on the link and finishes it on every exit
    /// path — the link (and its node fleet) outlives the run and keeps
    /// serving other ServerApps. Run ids must be unique per link.
    pub fn run(
        &mut self,
        link: &Arc<SuperLink>,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        link.register_run(run_id);
        // Fail fast on id reuse: a finished run's id stays finished, so
        // proceeding would only time out waiting for refused tasks.
        anyhow::ensure!(
            link.run_active(run_id),
            "run id {run_id} already finished on this link — run ids must be unique per link"
        );
        let result = self.run_rounds(link, tracker, run_id);
        // Scope the shutdown to THIS run: concurrent runs sharing the
        // link are untouched.
        link.finish(run_id);
        result
    }

    fn run_rounds(
        &mut self,
        link: &Arc<SuperLink>,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        let cfg = self.config.clone();
        link.wait_for_nodes(cfg.min_nodes, cfg.round_timeout)?;
        let mut params = self.initial_parameters.clone();
        let mut history = History::default();

        for round in 1..=cfg.num_rounds {
            let nodes = link.nodes();
            anyhow::ensure!(
                nodes.len() >= cfg.min_nodes,
                "round {round}: only {} nodes connected",
                nodes.len()
            );

            // ---- fit phase ----
            let fit_nodes = self.sample(&nodes, cfg.fraction_fit, round);
            let mut fit_cfg = self.strategy.configure_fit(round);
            fit_cfg.push(("round".to_string(), ConfigValue::I64(round as i64)));
            // Cohort + per-target node id: lets client-side mods (e.g.
            // secure aggregation) coordinate pairwise state.
            let cohort = fit_nodes
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join(",");
            fit_cfg.push(("cohort".to_string(), ConfigValue::Str(cohort)));
            let task_ids: Vec<u64> = fit_nodes
                .iter()
                .map(|&node| {
                    let mut config = fit_cfg.clone();
                    config.push(("node_id".to_string(), ConfigValue::I64(node as i64)));
                    link.push_task(
                        node,
                        TaskIns {
                            task_id: 0,
                            run_id,
                            round,
                            task_type: TaskType::Fit,
                            // O(1) per node: records share tensor buffers.
                            parameters: params.clone(),
                            config,
                        },
                    )
                })
                .collect();
            // Stream results into the strategy's accumulator AS THEY
            // ARRIVE: aggregation overlaps stragglers, and the link's
            // result map drains incrementally instead of buffering the
            // cohort twice.
            let mut agg = self.strategy.begin_fit(round, &params);
            let mut fit_meta: Vec<(u64, u64, MetricRecord)> = Vec::with_capacity(task_ids.len());
            let accept_failures = cfg.accept_failures;
            link.for_each_result(run_id, &task_ids, cfg.round_timeout, |r| {
                if !r.error.is_empty() {
                    if accept_failures {
                        log::warn!("round {round}: node {} failed: {}", r.node_id, r.error);
                        return Ok(());
                    }
                    anyhow::bail!("round {round}: node {} failed: {}", r.node_id, r.error);
                }
                fit_meta.push((r.node_id, r.num_examples, r.metrics.clone()));
                agg.accumulate(FitRes {
                    node_id: r.node_id,
                    parameters: r.parameters,
                    num_examples: r.num_examples,
                    metrics: r.metrics,
                })
            })?;
            anyhow::ensure!(
                agg.count() > 0,
                "round {round}: no successful fit results"
            );
            params = agg.finalize()?;

            // Weighted fit metrics, in canonical (node-sorted) order —
            // identical to the batch path regardless of arrival order.
            fit_meta.sort_by_key(|(node_id, _, _)| *node_id);
            let fit_metrics = super::strategy::weighted_eval(
                &fit_meta
                    .iter()
                    .map(|(node_id, num_examples, metrics)| EvalRes {
                        node_id: *node_id,
                        loss: 0.0,
                        num_examples: *num_examples,
                        metrics: metrics.clone(),
                    })
                    .collect::<Vec<_>>(),
            )
            .1;

            // ---- evaluate phase ----
            let (eval_loss, eval_metrics, per_client_eval) = if cfg.fraction_evaluate > 0.0 {
                let eval_nodes = self.sample(&nodes, cfg.fraction_evaluate, round + (1 << 32));
                let eval_cfg = self.strategy.configure_evaluate(round);
                let task_ids: Vec<u64> = eval_nodes
                    .iter()
                    .map(|&node| {
                        link.push_task(
                            node,
                            TaskIns {
                                task_id: 0,
                                run_id,
                                round,
                                task_type: TaskType::Evaluate,
                                parameters: params.clone(),
                                config: eval_cfg.clone(),
                            },
                        )
                    })
                    .collect();
                let mut results = link.await_results(run_id, &task_ids, cfg.round_timeout)?;
                results.sort_by_key(|r| r.node_id);
                let mut eval_results = Vec::new();
                let mut per_client = Vec::new();
                for r in results {
                    if !r.error.is_empty() {
                        if cfg.accept_failures {
                            continue;
                        }
                        anyhow::bail!(
                            "round {round}: eval on node {} failed: {}",
                            r.node_id,
                            r.error
                        );
                    }
                    per_client.push((r.node_id, r.loss, r.metrics.clone()));
                    eval_results.push(EvalRes {
                        node_id: r.node_id,
                        loss: r.loss,
                        num_examples: r.num_examples,
                        metrics: r.metrics,
                    });
                }
                let (loss, metrics) = self.strategy.aggregate_evaluate(round, &eval_results);
                (Some(loss), metrics, per_client)
            } else {
                (None, Vec::new(), Vec::new())
            };

            // ---- tracking (hybrid mode, §5.2) ----
            if let Some(t) = tracker {
                for (k, v) in &fit_metrics {
                    t.add_scalar(k, *v, round);
                }
                if let Some(l) = eval_loss {
                    t.add_scalar("eval_loss", l, round);
                }
                for (k, v) in &eval_metrics {
                    t.add_scalar(&format!("eval_{k}"), *v, round);
                }
            }

            log::info!(
                "round {round}: strategy={} eval_loss={eval_loss:?}",
                self.strategy.name()
            );
            history.rounds.push(RoundRecord {
                round,
                fit_metrics,
                eval_loss,
                eval_metrics,
                per_client_eval,
            });
        }
        history.parameters = params;
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::strategy::{Aggregator, FedAvg};

    fn mk_app(rounds: u64, seed: u64) -> ServerApp {
        ServerApp::new(
            Box::new(FedAvg::new(Aggregator::host())),
            ServerConfig {
                num_rounds: rounds,
                min_nodes: 2,
                seed,
                ..Default::default()
            },
            ArrayRecord::from_flat(&[0.0; 4]),
        )
    }

    #[test]
    fn sampling_is_deterministic_and_sorted() {
        let app = mk_app(1, 7);
        let nodes: Vec<u64> = (1..=10).collect();
        let a = app.sample(&nodes, 0.5, 3);
        let b = app.sample(&nodes, 0.5, 3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.len(), 5);
        let c = app.sample(&nodes, 0.5, 4);
        assert_ne!(a, c, "different rounds sample differently");
    }

    #[test]
    fn sampling_fraction_bounds() {
        let app = mk_app(1, 7);
        let nodes: Vec<u64> = (1..=4).collect();
        assert_eq!(app.sample(&nodes, 1.0, 1).len(), 4);
        assert_eq!(app.sample(&nodes, 0.01, 1).len(), 1);
    }

    #[test]
    fn history_csv_shape() {
        let h = History {
            rounds: vec![RoundRecord {
                round: 1,
                fit_metrics: vec![("train_loss".into(), 0.5)],
                eval_loss: Some(0.4),
                eval_metrics: vec![("accuracy".into(), 0.8)],
                per_client_eval: vec![],
            }],
            parameters: ArrayRecord::from_flat(&[1.0]),
        };
        let csv = h.to_csv();
        assert!(csv.starts_with("round,eval_loss,train_loss,eval_accuracy\n"));
        assert!(csv.contains("1,0.4,0.5,0.8"));
    }

    #[test]
    fn params_bits_equal_handles_nan() {
        let a = History {
            rounds: vec![],
            parameters: ArrayRecord::from_flat(&[f32::NAN]),
        };
        let b = History {
            rounds: vec![],
            parameters: ArrayRecord::from_flat(&[f32::NAN]),
        };
        assert!(a.params_bits_equal(&b));
        assert_eq!(a, b, "record equality is byte equality — NaN-safe");
        assert!(!a.params_bits_equal(&History {
            rounds: vec![],
            parameters: ArrayRecord::from_flat(&[0.0]),
        }));
    }
}
