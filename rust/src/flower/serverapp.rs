//! Flower ServerApp (paper Listing 1): drives FL rounds against a
//! [`Grid`] using a [`Strategy`]. Produces a [`History`] — the loss /
//! accuracy curves compared in Fig. 5 — and optionally streams round
//! metrics through FLARE experiment tracking (§5.2 hybrid mode).
//!
//! The ServerApp never touches the SuperLink directly: every push and
//! every reply goes through the [`Grid`] trait, so the same driver code
//! runs natively (the SuperLink IS the grid) and bridged
//! ([`crate::bridge::BridgedGrid`] — the FLARE LGC hop chain is an
//! implementation detail below this line, exactly the paper's Fig. 4).
//!
//! Fit results are **streamed**: each reply [`Message`] is handed to the
//! strategy's incremental accumulator as it arrives
//! ([`Grid::for_each_reply`]), so aggregation work overlaps stragglers
//! and the driver never buffers the whole cohort itself.
//! Each ServerApp drives ONE run (its `run_id`) and may share the
//! grid — and its SuperNode fleet — with any number of concurrent
//! ServerApps; finishing this run leaves the others untouched.
//!
//! Determinism: client sampling uses a seeded PRNG keyed by (seed,
//! round); accumulators canonicalize by node id before any
//! order-sensitive float reduction. Two runs with the same seed —
//! regardless of transport (native or bridged) or result arrival order —
//! produce bit-identical histories, which is exactly the paper's
//! reproducibility experiment.
//!
//! Parameters are [`ArrayRecord`]s end to end: pushing a round's model
//! to N clients clones the record N times, which is N cheap reference
//! bumps on the shared tensor buffers — not N payload copies.

use std::collections::HashSet;
use std::time::Duration;

use crate::flare::tracking::SummaryWriter;
use crate::flower::asyncfed::AsyncCommit;
use crate::flower::committee::{self, CommitteeConfig, Verdict};
use crate::flower::grid::Grid;
use crate::flower::message::{ConfigValue, Message, MetricRecord};
use crate::flower::persist::checkpoint::{DriverCkpt, DriverPhase, FitCkpt};
use crate::flower::records::{ArrayRecord, WireCodec, WIRE_CODEC_KEY};
use crate::flower::strategy::{AggSnapshot, EvalRes, FitRes, Strategy};
use crate::flower::superlink::{CompletionPolicy, ResultTimeout};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub num_rounds: u64,
    /// Fraction of connected nodes sampled for fit each round (1.0 = all).
    pub fraction_fit: f64,
    /// Fraction sampled for evaluate (0.0 disables federated evaluation).
    pub fraction_evaluate: f64,
    /// Wait for at least this many nodes before round 1.
    pub min_nodes: usize,
    pub round_timeout: Duration,
    /// Sampling seed — the "same random seeds" of the paper's Fig. 5.
    pub seed: u64,
    /// Fail the round if any sampled client errors (kept strict for
    /// reproducibility; Flower tolerates stragglers by default).
    pub accept_failures: bool,
    /// Partial participation quorum: the minimum number of DISTINCT
    /// nodes whose fit results must reach the accumulator for a round to
    /// finalize when sampled nodes die mid-round. 0 = strict mode (every
    /// sampled node must report — the pre-resilience behaviour, and what
    /// reproducibility experiments should use). Ignored (with a warning)
    /// when the strategy cannot aggregate a partial cohort (secure
    /// aggregation's pairwise masks only cancel over the full cohort).
    pub min_available: usize,
    /// Once the quorum is met, keep waiting for stragglers at most this
    /// long before finalizing without them.
    pub straggler_grace: Duration,
    /// Uplink wire codec negotiated to every fit instruction (the
    /// [`WIRE_CODEC_KEY`] config key): clients compress their result
    /// parameters with it, and the streaming accumulator dequantizes as
    /// it folds. `Identity` (default) keeps the wire uncompressed and
    /// bit-identical to every pre-codec run. Lossy codecs are refused
    /// up front for strategies whose reduction cannot survive
    /// quantization (see [`Strategy::supports_lossy_codec`]).
    pub codec: WireCodec,
    /// Per-round committee validation (`None` = off): completed fit
    /// updates are cross-scored by a deterministic seeded validator
    /// committee and outliers quarantined BEFORE aggregation (see
    /// [`crate::flower::committee`]). Quarantine is a content-level
    /// exclusion, so strategies that must see every contribution
    /// (secure aggregation) are refused up front
    /// ([`Strategy::supports_byzantine`]).
    pub committee: Option<CommitteeConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            num_rounds: 3,
            fraction_fit: 1.0,
            fraction_evaluate: 1.0,
            min_nodes: 2,
            round_timeout: Duration::from_secs(600),
            seed: 17,
            accept_failures: false,
            min_available: 0,
            straggler_grace: Duration::from_secs(2),
            codec: WireCodec::Identity,
            committee: None,
        }
    }
}

/// Per-round participation accounting: how much of the sampled fit
/// cohort actually contributed. In a clean run `completed == sampled`;
/// under churn the quorum path records exactly who was lost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Participation {
    /// Nodes sampled into the fit cohort.
    pub sampled: usize,
    /// Distinct nodes whose successful fit results reached the
    /// accumulator.
    pub completed: usize,
    /// Sampled nodes that never contributed (dead, failed, or cut off
    /// as stragglers after the quorum).
    pub dropped: usize,
    /// Nodes whose results ARRIVED but were excluded from aggregation
    /// by committee validation (0 when the committee is off).
    pub quarantined: usize,
}

/// One round's record in the history.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundRecord {
    pub round: u64,
    /// Example-weighted mean of client-reported fit metrics.
    pub fit_metrics: MetricRecord,
    /// Example-weighted federated evaluation loss (None if disabled).
    pub eval_loss: Option<f64>,
    pub eval_metrics: MetricRecord,
    /// Per-client evaluation (node_id, loss, metrics) — Fig. 6 series.
    pub per_client_eval: Vec<(u64, f64, MetricRecord)>,
    /// Fit-cohort participation for this round.
    pub participation: Participation,
    /// Committee validation verdicts for the completed fit cohort,
    /// sorted by node id (empty when committee validation is off).
    pub verdicts: Vec<Verdict>,
}

/// The training curves of Fig. 5. `PartialEq` compares final parameters
/// byte-exactly (record equality is payload-bit equality), which IS the
/// bit-exact overlay check.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct History {
    pub rounds: Vec<RoundRecord>,
    /// Async-mode commit log (one entry per global model commit; empty
    /// for synchronous runs). See [`crate::flower::asyncfed`].
    pub commits: Vec<AsyncCommit>,
    /// Final global parameters.
    pub parameters: ArrayRecord,
}

impl History {
    /// CSV of the aggregated curves (round, fit metrics..., eval loss/metrics).
    pub fn to_csv(&self) -> String {
        let mut keys: Vec<String> = Vec::new();
        for r in &self.rounds {
            for (k, _) in r.fit_metrics.iter() {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
            for (k, _) in r.eval_metrics.iter() {
                let k = format!("eval_{k}");
                if !keys.contains(&k) {
                    keys.push(k);
                }
            }
        }
        let mut out = String::from("round,eval_loss");
        for k in &keys {
            out.push(',');
            out.push_str(k);
        }
        out.push('\n');
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{}",
                r.round,
                r.eval_loss.map(|l| l.to_string()).unwrap_or_default()
            ));
            for k in &keys {
                out.push(',');
                let v = if let Some(stripped) = k.strip_prefix("eval_") {
                    r.eval_metrics
                        .iter()
                        .find(|(mk, _)| mk == stripped)
                        .map(|(_, v)| *v)
                } else {
                    r.fit_metrics.iter().find(|(mk, _)| mk == k).map(|(_, v)| *v)
                };
                if let Some(v) = v {
                    out.push_str(&v.to_string());
                }
            }
            out.push('\n');
        }
        out
    }

    /// Bitwise equality of the final parameters (NaN-safe; kept for API
    /// clarity even though record `PartialEq` is already byte-exact).
    pub fn params_bits_equal(&self, other: &History) -> bool {
        self.parameters.bits_equal(&other.parameters)
    }
}

/// Completion policy for one phase: strict when no quorum is set,
/// otherwise a quorum clamped to the cohort actually sampled this phase
/// (a quorum larger than the cohort would be unreachable and burn the
/// whole round timeout).
fn phase_policy(quorum: usize, cohort: usize, grace: Duration) -> CompletionPolicy {
    if quorum == 0 {
        CompletionPolicy::all()
    } else {
        CompletionPolicy::quorum(quorum.min(cohort).max(1), grace)
    }
}

/// The ServerApp: strategy + config + initial parameters (paper
/// Listing 1: `ServerApp(config=ServerConfig(num_rounds=3), strategy=...)`).
pub struct ServerApp {
    pub strategy: Box<dyn Strategy>,
    pub config: ServerConfig,
    pub initial_parameters: ArrayRecord,
}

impl ServerApp {
    pub fn new(
        strategy: Box<dyn Strategy>,
        config: ServerConfig,
        initial_parameters: ArrayRecord,
    ) -> Self {
        Self {
            strategy,
            config,
            initial_parameters,
        }
    }

    /// Deterministic sample of `k` nodes for a round.
    fn sample(&self, nodes: &[u64], fraction: f64, round: u64) -> Vec<u64> {
        let k = ((nodes.len() as f64 * fraction).ceil() as usize)
            .clamp(1, nodes.len());
        let mut rng = Rng::new(self.config.seed).split(round);
        let mut idx = rng.sample_indices(nodes.len(), k);
        idx.sort_unstable(); // canonical order
        idx.into_iter().map(|i| nodes[i]).collect()
    }

    /// Run all rounds against the grid (native: pass `&link`; bridged:
    /// pass the [`crate::bridge::BridgedGrid`]). `tracker` streams round
    /// metrics via FLARE experiment tracking when present (§5.2).
    ///
    /// Opens run `run_id` on the grid and finishes it on every exit
    /// path — the grid (and its node fleet) outlives the run and keeps
    /// serving other ServerApps. Run ids must be unique per grid.
    pub fn run<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        grid.open_run(run_id);
        // Fail fast on id reuse: a finished run's id stays finished, so
        // proceeding would only time out waiting for refused tasks.
        anyhow::ensure!(
            grid.run_active(run_id),
            "run id {run_id} already finished on this link — run ids must be unique per link"
        );
        let result = self.run_rounds(grid, tracker, run_id);
        // Scope the shutdown to THIS run: concurrent runs sharing the
        // grid are untouched.
        grid.close_run(run_id);
        result
    }

    /// Like [`ServerApp::run`], but close the run ONLY on success: an
    /// error (a crash, or a simulated one) leaves the run open on the
    /// grid so [`ServerApp::resume`] can pick it up after recovery.
    /// On a durable grid with a snapshot-capable strategy, round-entry
    /// and mid-fit checkpoints are cut as the run progresses.
    pub fn run_durable<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        grid.open_run(run_id);
        anyhow::ensure!(
            grid.run_active(run_id),
            "run id {run_id} already finished on this link — run ids must be unique per link"
        );
        if grid.durable() && !self.strategy.supports_snapshot() {
            log::warn!(
                "strategy {} declines accumulator snapshots — mid-round \
                 checkpoints disabled for run {run_id}",
                self.strategy.name()
            );
        }
        let result = self.run_rounds(grid, tracker, run_id);
        if result.is_ok() {
            grid.close_run(run_id);
        }
        result
    }

    /// Resume a recovered run from its last driver checkpoint: import
    /// the strategy's optimizer state, restore the in-flight fit
    /// accumulator, reconcile the wait set against the grid's open
    /// tasks, and drive the remaining rounds. A resumed run finalizes
    /// bit-identical to one that was never interrupted.
    pub fn resume<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        anyhow::ensure!(grid.durable(), "resume requires a durable grid");
        anyhow::ensure!(
            grid.run_active(run_id),
            "run {run_id} already finished — nothing to resume"
        );
        let blob = grid.driver_checkpoint(run_id).ok_or_else(|| {
            anyhow::anyhow!("run {run_id}: no driver checkpoint to resume from")
        })?;
        let ck = DriverCkpt::decode(&blob)?;
        if let Some(state) = &ck.strategy_state {
            self.strategy.import_state(state)?;
        }
        let (start_round, resume_fit) = match ck.phase {
            DriverPhase::RoundStart => (ck.round, None),
            DriverPhase::MidFit(fit) => (ck.round, Some(fit)),
            DriverPhase::AsyncCommit(_) => anyhow::bail!(
                "run {run_id}: checkpoint belongs to the async driver — \
                 resume it with the async entry point"
            ),
        };
        log::info!(
            "run {run_id}: resuming at round {start_round} ({})",
            if resume_fit.is_some() {
                "mid-fit"
            } else {
                "round start"
            }
        );
        let result = self.run_rounds_from(
            grid,
            tracker,
            run_id,
            start_round,
            ck.parameters,
            ck.history,
            resume_fit,
        );
        if result.is_ok() {
            grid.close_run(run_id);
        }
        result
    }

    fn run_rounds<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
    ) -> anyhow::Result<History> {
        let params = self.initial_parameters.clone();
        self.run_rounds_from(grid, tracker, run_id, 1, params, History::default(), None)
    }

    /// Drive rounds `start_round..=num_rounds` from an explicit driver
    /// state — the shared engine behind [`ServerApp::run`] (fresh
    /// state) and [`ServerApp::resume`] (state decoded from the last
    /// checkpoint; `resume_fit` re-enters a half-finished fit phase).
    #[allow(clippy::too_many_arguments)]
    fn run_rounds_from<G: Grid + ?Sized>(
        &mut self,
        grid: &G,
        tracker: Option<&SummaryWriter>,
        run_id: u64,
        start_round: u64,
        mut params: ArrayRecord,
        mut history: History,
        mut resume_fit: Option<FitCkpt>,
    ) -> anyhow::Result<History> {
        // Sharded grids merge per-shard partial aggregates at a root;
        // a strategy that cannot merge partials (secagg) must be
        // refused up front, not finalize mask residue.
        anyhow::ensure!(
            grid.shard_count() == 1 || self.strategy.supports_sharding(),
            "strategy {} cannot aggregate across {} shards (e.g. secure aggregation \
             masks only cancel when one aggregator sees the full cohort) — \
             run it on a single link",
            self.strategy.name(),
            grid.shard_count()
        );
        // Same up-front refusal for lossy wire codecs: a reduction
        // whose inputs must arrive bit-exact (secagg's pairwise masks)
        // would silently produce garbage from quantized results.
        anyhow::ensure!(
            !self.config.codec.is_lossy() || self.strategy.supports_lossy_codec(),
            "strategy {} cannot aggregate lossy '{}' wire-codec results \
             (e.g. secure aggregation masks do not survive quantization) — \
             use the identity or delta codec",
            self.strategy.name(),
            self.config.codec.name()
        );
        // Committee validation EXCLUDES quarantined updates from the
        // fold — a content-level partial cohort. Strategies that must
        // see every contribution are refused up front.
        anyhow::ensure!(
            self.config.committee.is_none() || self.strategy.supports_byzantine(),
            "strategy {} cannot aggregate a committee-filtered cohort (e.g. secure \
             aggregation masks only cancel when every contribution folds) — \
             disable committee validation",
            self.strategy.name()
        );
        let cfg = self.config.clone();
        grid.wait_for_nodes(cfg.min_nodes, cfg.round_timeout)?;
        // Mid-round durability requires the strategy to snapshot its
        // accumulator; a declining strategy still runs, just without
        // driver checkpoints.
        let durable = grid.durable() && self.strategy.supports_snapshot();

        // Partial participation: only when a quorum is configured AND the
        // strategy can aggregate a strict subset of the cohort.
        let partial_ok = self.strategy.supports_partial();
        if cfg.min_available > 0 && !partial_ok {
            log::warn!(
                "strategy {} cannot finalize a partial cohort (e.g. secagg masks \
                 only cancel over the full cohort) — ignoring min_available={}",
                self.strategy.name(),
                cfg.min_available
            );
        }
        let quorum = if partial_ok { cfg.min_available } else { 0 };
        // With a quorum the fleet may legitimately shrink below
        // `min_nodes` mid-run; the quorum is then the per-round floor.
        let round_floor = if quorum > 0 { quorum } else { cfg.min_nodes };

        for round in start_round..=cfg.num_rounds {
            // Reap first so this round's cohort is sampled from nodes
            // that are actually alive — a task pushed to an already-dead
            // node would otherwise strand until the grace/timeout.
            grid.reap();
            let nodes = grid.node_ids();
            anyhow::ensure!(
                nodes.len() >= round_floor,
                "round {round}: only {} nodes connected",
                nodes.len()
            );

            // ---- fit phase ----
            let resumed_fit = resume_fit.take();
            let resuming = resumed_fit.is_some();
            // Strategy state is exported BEFORE the accumulator borrows
            // the strategy mutably. It names the PRE-round state: a
            // resumed run imports it and `finalize` then applies this
            // round's optimizer step exactly once.
            let strategy_state = if durable {
                self.strategy.export_state()
            } else {
                None
            };
            if durable && !resuming {
                // Round-entry checkpoint: a crash anywhere before the
                // first mid-fit checkpoint resumes from here.
                let ck = DriverCkpt {
                    round,
                    parameters: params.clone(),
                    history: history.clone(),
                    strategy_state: strategy_state.clone(),
                    phase: DriverPhase::RoundStart,
                };
                grid.checkpoint_run(run_id, ck.encode());
            }
            // Stream results into the strategy's accumulator AS THEY
            // ARRIVE: aggregation overlaps stragglers, and the link's
            // result map drains incrementally instead of buffering the
            // cohort twice. One result per NODE: if a dead node's task
            // was redelivered to a node that already contributed, the
            // duplicate contribution is skipped, so a partial round
            // aggregates exactly the surviving cohort.
            let (task_ids, mut agg, mut fit_meta, mut seen_nodes) = match resumed_fit {
                Some(ck) => {
                    // Re-enter the half-finished fit phase: same task
                    // ids, accumulator restored to the checkpointed
                    // fold state.
                    let mut agg = self.strategy.begin_fit(round, &params);
                    agg.restore(AggSnapshot::Fit(ck.results))?;
                    let seen: HashSet<u64> =
                        ck.fit_meta.iter().map(|(node, _, _)| *node).collect();
                    (ck.task_ids, agg, ck.fit_meta, seen)
                }
                None => {
                    let fit_nodes = self.sample(&nodes, cfg.fraction_fit, round);
                    let mut fit_cfg = self.strategy.configure_fit(round);
                    fit_cfg.push(("round".to_string(), ConfigValue::I64(round as i64)));
                    // Negotiate the uplink codec: clients compress
                    // their reply parameters with it (identity rides
                    // implicitly — zero config bytes, bit-identical to
                    // pre-codec rounds).
                    if cfg.codec != WireCodec::Identity {
                        fit_cfg.push((
                            WIRE_CODEC_KEY.to_string(),
                            ConfigValue::Str(cfg.codec.name().to_string()),
                        ));
                    }
                    // Cohort + per-target node id: lets client-side mods
                    // (e.g. secure aggregation) coordinate pairwise
                    // state.
                    let cohort = fit_nodes
                        .iter()
                        .map(|n| n.to_string())
                        .collect::<Vec<_>>()
                        .join(",");
                    fit_cfg.push(("cohort".to_string(), ConfigValue::Str(cohort)));
                    let task_ids: Vec<u64> = fit_nodes
                        .iter()
                        .map(|&node| {
                            let mut config = fit_cfg.clone();
                            config.push(("node_id".to_string(), ConfigValue::I64(node as i64)));
                            // Train message defaults: node-affine (no
                            // redelivery — each node trains on ITS data)
                            // and version-less (sync rounds; the async
                            // driver is the only version author).
                            // Cloning `params` is O(1) per node: records
                            // share tensor buffers.
                            grid.push_message(
                                Message::train(node, params.clone(), config)
                                    .for_round(run_id, round),
                            )
                        })
                        .collect();
                    let cap = task_ids.len();
                    let agg = self.strategy.begin_fit(round, &params);
                    (
                        task_ids,
                        agg,
                        Vec::with_capacity(cap),
                        HashSet::with_capacity(cap),
                    )
                }
            };
            let sampled = task_ids.len();
            let accept_failures = cfg.accept_failures;
            let fit_quorum = quorum.min(sampled);
            if quorum > sampled {
                // Don't silently under-enforce the operator's floor.
                log::warn!(
                    "round {round}: min_available {quorum} exceeds the sampled fit \
                     cohort of {sampled} (fraction_fit too small?) — enforcing {fit_quorum}"
                );
            }
            let fit_policy = phase_policy(quorum, sampled, cfg.straggler_grace);
            // A resumed wait covers only tasks still OPEN on the grid:
            // results folded before the checkpoint are already done
            // (waiting on them would hang forever), while accepted-but-
            // unfolded results and re-queued tasks are open and flow
            // back through the callback exactly once.
            let wait_ids: Vec<u64> = if resuming {
                let open: HashSet<u64> = grid
                    .open_tasks(run_id)
                    .into_iter()
                    .map(|(id, _, _)| id)
                    .collect();
                task_ids
                    .iter()
                    .copied()
                    .filter(|id| open.contains(id))
                    .collect()
            } else {
                task_ids.clone()
            };
            // Mid-fit checkpoint capture basis (cheap clones: records
            // share tensor buffers).
            let ckpt_params = params.clone();
            let ckpt_history = history.clone();
            let all_task_ids = task_ids.clone();
            // Committee-gated rounds defer every fold until the full
            // completed cohort is scored at phase end.
            let committee_cfg = cfg.committee;
            let mut pending: Vec<FitRes> = Vec::new();
            let wait = grid.for_each_reply(
                run_id,
                &wait_ids,
                cfg.round_timeout,
                fit_policy,
                &mut |r: Message| {
                    let node = r.metadata.src_node_id;
                    if !r.error.is_empty() {
                        if accept_failures {
                            log::warn!("round {round}: node {node} failed: {}", r.error);
                            return Ok(());
                        }
                        anyhow::bail!("round {round}: node {node} failed: {}", r.error);
                    }
                    if !seen_nodes.insert(node) {
                        crate::telemetry::bump("serverapp.duplicate_node_results_skipped", 1);
                        log::warn!(
                            "round {round}: node {node} delivered a second \
                             (redelivered) result — skipped"
                        );
                        return Ok(());
                    }
                    // Delta-encoded replies resolve against THIS
                    // round's pushed model — the very record the node
                    // encoded against (XOR is lossless, so the resolved
                    // tensors are bit-identical to an uncompressed
                    // reply). A base/version mismatch is a typed
                    // per-node refusal, honoring accept_failures.
                    let arrays = match r
                        .content
                        .arrays
                        .resolve_delta(&ckpt_params, r.metadata.model_version)
                    {
                        Ok(a) => a,
                        Err(e) => {
                            seen_nodes.remove(&node);
                            if accept_failures {
                                log::warn!("round {round}: node {node} refused: {e}");
                                return Ok(());
                            }
                            anyhow::bail!("round {round}: node {node} refused: {e}");
                        }
                    };
                    let num_examples = r.metadata.num_examples;
                    let res = FitRes {
                        node_id: node,
                        parameters: arrays,
                        num_examples,
                        metrics: r.content.metrics,
                    };
                    if committee_cfg.is_some() {
                        // Buffer for phase-end validation; fit_meta is
                        // deferred too, so quarantined updates shape
                        // neither the model nor the metrics.
                        pending.push(res);
                        return Ok(());
                    }
                    fit_meta.push((node, num_examples, res.metrics.clone()));
                    agg.accumulate(res)?;
                    // Mid-fit checkpoint: the accumulator's fold state
                    // rides in the driver blob, cut atomically with the
                    // link's own snapshot (one consistent pair).
                    if durable && grid.checkpoint_due(run_id) {
                        if let Some(AggSnapshot::Fit(results)) = agg.snapshot() {
                            let ck = DriverCkpt {
                                round,
                                parameters: ckpt_params.clone(),
                                history: ckpt_history.clone(),
                                strategy_state: strategy_state.clone(),
                                phase: DriverPhase::MidFit(FitCkpt {
                                    task_ids: all_task_ids.clone(),
                                    results,
                                    fit_meta: fit_meta.clone(),
                                }),
                            };
                            grid.checkpoint_run(run_id, ck.encode());
                        }
                    }
                    Ok(())
                },
            )?;
            if quorum == 0 && !wait.is_complete() {
                // Strict mode: preserve the pre-resilience contract —
                // the typed error still carries the wait outcome.
                return Err(ResultTimeout {
                    run_id,
                    missing: wait.missing,
                    failed: wait.failed,
                    partial: Vec::new(),
                }
                .into());
            }
            // ---- committee validation (content-level gate) ----
            // The committee scores the COMPLETED cohort — a pure
            // function of the node-id-sorted result set, so the
            // verdicts (and the surviving fold) are identical on any
            // transport and in any arrival order.
            let mut verdicts: Vec<Verdict> = Vec::new();
            let mut quarantined_count = 0usize;
            if let Some(cc) = &committee_cfg {
                verdicts = committee::validate(cc, cfg.seed, run_id, round, &pending);
                let quarantined = committee::quarantined_nodes(&verdicts);
                quarantined_count = quarantined.len();
                // Fold survivors in canonical node-id order.
                pending.sort_by_key(|r| r.node_id);
                for res in pending.drain(..) {
                    if quarantined.contains(&res.node_id) {
                        continue;
                    }
                    fit_meta.push((res.node_id, res.num_examples, res.metrics.clone()));
                    agg.accumulate(res)?;
                }
            }
            anyhow::ensure!(
                agg.count() > 0,
                "round {round}: no successful fit results"
            );
            anyhow::ensure!(
                quorum == 0 || agg.count() >= fit_quorum,
                "round {round}: only {} of {sampled} fit results (quorum {fit_quorum}; \
                 {} failed, {} missing)",
                agg.count(),
                wait.failed.len(),
                wait.missing.len()
            );
            // Strict mode demands the FULL cohort, not just a fully
            // resolved wait: a dead node's task "completing" through a
            // redelivered substitute (whose duplicate contribution is
            // skipped above) must not pass as a clean round.
            if quorum == 0 && !accept_failures {
                // Quarantined results ARRIVED — exclusion by verdict is
                // not a missing contribution.
                anyhow::ensure!(
                    fit_meta.len() + quarantined_count == task_ids.len(),
                    "round {round}: only {} of {} sampled nodes contributed distinct \
                     results (a dead node's task was redelivered) — strict mode \
                     requires the full cohort",
                    fit_meta.len() + quarantined_count,
                    task_ids.len()
                );
            }
            let participation = Participation {
                sampled,
                completed: fit_meta.len(),
                dropped: sampled.saturating_sub(fit_meta.len() + quarantined_count),
                quarantined: quarantined_count,
            };
            // Gate on quorum: in strict mode a shortfall is either an
            // error above or an accept_failures-tolerated client error,
            // not a quorum finalization.
            if participation.dropped > 0 && quorum > 0 {
                crate::telemetry::bump("serverapp.partial_rounds", 1);
                log::warn!(
                    "round {round}: finalizing at quorum — {} of {} sampled nodes contributed",
                    participation.completed,
                    participation.sampled
                );
            }
            params = agg.finalize()?;

            // Weighted fit metrics, in canonical (node-sorted) order —
            // identical to the batch path regardless of arrival order.
            fit_meta.sort_by_key(|(node_id, _, _)| *node_id);
            let fit_metrics = super::strategy::weighted_eval(
                &fit_meta
                    .iter()
                    .map(|(node_id, num_examples, metrics)| EvalRes {
                        node_id: *node_id,
                        loss: 0.0,
                        num_examples: *num_examples,
                        metrics: metrics.clone(),
                    })
                    .collect::<Vec<_>>(),
            )
            .1;

            // ---- evaluate phase ----
            // Sample from the CURRENT pool: nodes that died during the
            // fit phase were reaped by its wait loop, and a task pushed
            // to a dead node would strand until the grace/timeout. In a
            // clean run this equals the round-start list, so histories
            // are unchanged.
            let eval_basis = grid.node_ids();
            let (eval_loss, eval_metrics, per_client_eval) = if cfg.fraction_evaluate > 0.0
                && !eval_basis.is_empty()
            {
                let eval_nodes = self.sample(&eval_basis, cfg.fraction_evaluate, round + (1 << 32));
                let eval_cfg = self.strategy.configure_evaluate(round);
                let task_ids: Vec<u64> = eval_nodes
                    .iter()
                    .map(|&node| {
                        grid.push_message(
                            Message::evaluate(node, params.clone(), eval_cfg.clone())
                                .for_round(run_id, round),
                        )
                    })
                    .collect();
                // Same completion semantics as fit (quorum clamped to
                // the eval cohort, which is often smaller): with a
                // quorum, missing evaluations shrink the weighted mean
                // instead of failing the round. Results STREAM into the
                // strategy's eval accumulator as they arrive — each
                // TaskRes frame is reduced to a few floats on the spot,
                // so a quorum eval wait no longer buffers the cohort's
                // full frames (the fit-phase fix, applied to eval).
                let eval_policy = phase_policy(quorum, task_ids.len(), cfg.straggler_grace);
                let mut eval_agg = self.strategy.begin_evaluate(round);
                let mut per_client: Vec<(u64, f64, MetricRecord)> = Vec::new();
                // One evaluation per node, mirroring the fit path: a
                // redelivered eval executed by a node that already
                // evaluated must not double its weight in the mean.
                let mut seen_eval: HashSet<u64> = HashSet::with_capacity(task_ids.len());
                let eval_wait = grid.for_each_reply(
                    run_id,
                    &task_ids,
                    cfg.round_timeout,
                    eval_policy,
                    &mut |r: Message| {
                        let node = r.metadata.src_node_id;
                        if !r.error.is_empty() {
                            if accept_failures {
                                return Ok(());
                            }
                            anyhow::bail!(
                                "round {round}: eval on node {node} failed: {}",
                                r.error
                            );
                        }
                        if !seen_eval.insert(node) {
                            crate::telemetry::bump(
                                "serverapp.duplicate_node_results_skipped",
                                1,
                            );
                            return Ok(());
                        }
                        let loss = r.metadata.loss;
                        per_client.push((node, loss, r.content.metrics.clone()));
                        eval_agg.accumulate(EvalRes {
                            node_id: node,
                            loss,
                            num_examples: r.metadata.num_examples,
                            metrics: r.content.metrics,
                        });
                        Ok(())
                    },
                )?;
                if quorum == 0 && !eval_wait.is_complete() {
                    // Strict mode: fail — the typed error reports the
                    // unresolved ids (payloads already streamed).
                    return Err(ResultTimeout {
                        run_id,
                        missing: eval_wait.missing,
                        failed: eval_wait.failed,
                        partial: Vec::new(),
                    }
                    .into());
                }
                if quorum == 0 && !cfg.accept_failures {
                    anyhow::ensure!(
                        eval_agg.count() == task_ids.len(),
                        "round {round}: only {} of {} sampled nodes evaluated \
                         (a dead node's task was redelivered) — strict mode \
                         requires the full cohort",
                        eval_agg.count(),
                        task_ids.len()
                    );
                }
                // Canonical (node-sorted) per-client series, independent
                // of arrival order — what the batch path recorded.
                per_client.sort_by_key(|(node_id, _, _)| *node_id);
                if eval_agg.count() == 0 {
                    // Every sampled evaluator died or errored: record
                    // "no evaluation" instead of a fabricated 0.0 loss.
                    log::warn!("round {round}: no evaluation results — eval_loss omitted");
                    (None, Vec::new(), per_client)
                } else {
                    let (loss, metrics) = eval_agg.finalize();
                    (Some(loss), metrics, per_client)
                }
            } else {
                (None, Vec::new(), Vec::new())
            };

            // ---- tracking (hybrid mode, §5.2) ----
            if let Some(t) = tracker {
                for (k, v) in &fit_metrics {
                    t.add_scalar(k, *v, round);
                }
                if let Some(l) = eval_loss {
                    t.add_scalar("eval_loss", l, round);
                }
                for (k, v) in &eval_metrics {
                    t.add_scalar(&format!("eval_{k}"), *v, round);
                }
            }

            log::info!(
                "round {round}: strategy={} eval_loss={eval_loss:?}",
                self.strategy.name()
            );
            history.rounds.push(RoundRecord {
                round,
                fit_metrics,
                eval_loss,
                eval_metrics,
                per_client_eval,
                participation,
                verdicts,
            });
        }
        history.parameters = params;
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::strategy::{Aggregator, FedAvg};

    fn mk_app(rounds: u64, seed: u64) -> ServerApp {
        ServerApp::new(
            Box::new(FedAvg::new(Aggregator::host())),
            ServerConfig {
                num_rounds: rounds,
                min_nodes: 2,
                seed,
                ..Default::default()
            },
            ArrayRecord::from_flat(&[0.0; 4]),
        )
    }

    #[test]
    fn sampling_is_deterministic_and_sorted() {
        let app = mk_app(1, 7);
        let nodes: Vec<u64> = (1..=10).collect();
        let a = app.sample(&nodes, 0.5, 3);
        let b = app.sample(&nodes, 0.5, 3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.len(), 5);
        let c = app.sample(&nodes, 0.5, 4);
        assert_ne!(a, c, "different rounds sample differently");
    }

    #[test]
    fn sampling_fraction_bounds() {
        let app = mk_app(1, 7);
        let nodes: Vec<u64> = (1..=4).collect();
        assert_eq!(app.sample(&nodes, 1.0, 1).len(), 4);
        assert_eq!(app.sample(&nodes, 0.01, 1).len(), 1);
    }

    #[test]
    fn history_csv_shape() {
        let h = History {
            rounds: vec![RoundRecord {
                round: 1,
                fit_metrics: vec![("train_loss".to_string(), 0.5)].into(),
                eval_loss: Some(0.4),
                eval_metrics: vec![("accuracy".to_string(), 0.8)].into(),
                per_client_eval: vec![],
                participation: Participation::default(),
                verdicts: vec![],
            }],
            commits: vec![],
            parameters: ArrayRecord::from_flat(&[1.0]),
        };
        let csv = h.to_csv();
        assert!(csv.starts_with("round,eval_loss,train_loss,eval_accuracy\n"));
        assert!(csv.contains("1,0.4,0.5,0.8"));
    }

    #[test]
    fn params_bits_equal_handles_nan() {
        let a = History {
            parameters: ArrayRecord::from_flat(&[f32::NAN]),
            ..Default::default()
        };
        let b = History {
            parameters: ArrayRecord::from_flat(&[f32::NAN]),
            ..Default::default()
        };
        assert!(a.params_bits_equal(&b));
        assert_eq!(a, b, "record equality is byte equality — NaN-safe");
        assert!(!a.params_bits_equal(&History {
            parameters: ArrayRecord::from_flat(&[0.0]),
            ..Default::default()
        }));
    }
}
