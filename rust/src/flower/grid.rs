//! The **Grid**: the driver-facing federation abstraction (Flower's
//! `Grid` API). A ServerApp — synchronous rounds, the async FedBuff
//! driver, or a federated-analytics query run — pushes instruction
//! [`Message`]s to nodes and pulls/streams their replies through this
//! trait, and ONLY this trait: where the fleet actually lives is an
//! implementation detail.
//!
//! Two implementations exist, mirroring the paper's Fig. 4:
//!
//! * **native** — [`SuperLink`] itself implements `Grid`: the driver
//!   sits in the same process as the link and the SuperNode fleet dials
//!   it directly (Fig. 5a).
//! * **bridged** — [`crate::bridge::BridgedGrid`] wraps a SuperLink
//!   whose client traffic arrives through FLARE reliable messaging (the
//!   LGS→SCP→LGC hop chain of Fig. 4). Constructing it wires the LGC;
//!   the driver code is unchanged — the six-hop bridge is invisible
//!   above this trait.
//!
//! # Example
//!
//! Drive a query round against a native grid by hand (what
//! [`crate::flower::analytics::run_query`] automates; a real deployment
//! lets SuperNodes answer instead of crafting frames):
//!
//! ```
//! use flarelink::flower::grid::Grid;
//! use flarelink::flower::message::{ConfigRecord, FlowerMsg, Message};
//! use flarelink::flower::records::RecordDict;
//! use flarelink::flower::superlink::SuperLink;
//!
//! let link = SuperLink::new();
//! // A node joins (normally a SuperNode does this over its connector).
//! link.handle_frame(&FlowerMsg::CreateNode { requested: 0 }.encode());
//! link.open_run(1);
//! let ids = link.push_messages(vec![
//!     Message::query(1, ConfigRecord::new()).for_round(1, 1),
//! ]);
//! // The node pulls and answers (normally the Router's query handler).
//! let pull = link.handle_frame(&FlowerMsg::PullTaskIns { node_id: 1 }.encode());
//! let ins = match FlowerMsg::decode(&pull).unwrap() {
//!     FlowerMsg::TaskInsList { tasks, .. } => tasks.into_iter().next().unwrap(),
//!     other => panic!("{other:?}"),
//! };
//! let reply = Message::from_ins(ins, 1)
//!     .reply(RecordDict::default())
//!     .with_examples(3);
//! link.handle_frame(&FlowerMsg::PushTaskRes { res: reply.into_res() }.encode());
//! // The driver claims the reply.
//! let (replies, failed) = link.pull_messages(1, &ids);
//! assert!(failed.is_empty());
//! assert_eq!(replies[0].metadata.num_examples, 3);
//! assert_eq!(replies[0].metadata.src_node_id, 1);
//! link.close_run(1);
//! ```

use std::sync::Arc;
use std::time::Duration;

use crate::flower::message::Message;
use crate::flower::superlink::{CompletionPolicy, RoundWait, SuperLink};

/// Driver-side federation surface: run lifecycle, node pool, message
/// push/pull. Object-safe — drivers that don't need generics can take
/// `&dyn Grid`.
pub trait Grid: Send + Sync {
    /// Open coordination state for `run_id` (idempotent while active).
    /// Run ids must be unique over a grid's lifetime.
    fn open_run(&self, run_id: u64);

    /// Is this run still accepting/serving messages?
    fn run_active(&self, run_id: u64) -> bool;

    /// Finish `run_id`: undelivered instructions and unconsumed replies
    /// are reclaimed; other runs are untouched.
    fn close_run(&self, run_id: u64);

    /// Live node ids, sorted (the deterministic sampling basis).
    fn node_ids(&self) -> Vec<u64>;

    /// Block until at least `n` nodes are connected.
    fn wait_for_nodes(&self, n: usize, timeout: Duration) -> anyhow::Result<Vec<u64>>;

    /// Declare nodes with expired liveness leases dead and settle their
    /// in-flight messages (redeliver or fail).
    fn reap(&self);

    /// Queue one instruction to `msg.metadata.dst_node_id` (run routed
    /// by `msg.metadata.run_id`); returns the message id replies carry.
    fn push_message(&self, msg: Message) -> u64;

    /// Queue a batch of instructions; returns their ids in order.
    fn push_messages(&self, msgs: Vec<Message>) -> Vec<u64> {
        msgs.into_iter().map(|m| self.push_message(m)).collect()
    }

    /// Non-blocking claim of whatever has resolved among `ids`: reply
    /// messages (ascending id) plus failed ids with reasons. Each reply
    /// is handed out exactly once. Pair with [`Grid::wait_activity`] to
    /// sleep between polls — the async driver's loop.
    fn pull_messages(&self, run_id: u64, ids: &[u64]) -> (Vec<Message>, Vec<(u64, String)>);

    /// Block until grid state changes (a reply arrives, a node joins or
    /// dies, a run finishes) or `timeout` passes.
    fn wait_activity(&self, timeout: Duration);

    /// Like [`Grid::wait_activity`], but scoped to one run where the
    /// grid supports it: the driver sleeps on that run's notify seat and
    /// is not woken by other runs' traffic. The default falls back to
    /// the any-change wait, so the contract ("wakes at least when this
    /// run changes") always holds.
    fn wait_activity_run(&self, _run_id: u64, timeout: Duration) {
        self.wait_activity(timeout);
    }

    /// How many interior aggregation shards serve this grid (1 = a flat
    /// single link). Strategies that cannot merge partial aggregates —
    /// see `supports_sharding` on
    /// [`crate::flower::strategy::Strategy`] — are refused by drivers
    /// when this exceeds 1.
    fn shard_count(&self) -> usize {
        1
    }

    /// Stream replies for `ids` to `f` AS THEY ARRIVE (arrival order);
    /// the [`CompletionPolicy`] decides when the wait may stop and the
    /// outcome is reported as data. Only a callback error aborts.
    fn for_each_reply(
        &self,
        run_id: u64,
        ids: &[u64],
        timeout: Duration,
        policy: CompletionPolicy,
        f: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<RoundWait>;

    // ---- Durability hooks (default: the grid is not durable) --------

    /// Does this grid journal state and accept driver checkpoints?
    /// Drivers only persist round state when this is `true`.
    fn durable(&self) -> bool {
        false
    }

    /// Is a checkpoint due (enough journaled results accumulated since
    /// the last one)? Always `false` on non-durable grids.
    fn checkpoint_due(&self, _run_id: u64) -> bool {
        false
    }

    /// Persist `blob` as the driver's round state for `run_id`,
    /// atomically with a full grid checkpoint. No-op when not durable.
    fn checkpoint_run(&self, _run_id: u64, _blob: Vec<u8>) {}

    /// The driver blob last checkpointed (or recovered) for `run_id`.
    fn driver_checkpoint(&self, _run_id: u64) -> Option<Vec<u8>> {
        None
    }

    /// Journal that the driver folded message `id` into its running
    /// aggregate (async drivers). No-op when not durable.
    fn journal_fold(&self, _run_id: u64, _id: u64) {}

    /// Journal that the driver committed global model `version` (async
    /// drivers). No-op when not durable.
    fn journal_commit(&self, _run_id: u64, _version: u64) {}

    /// Messages of `run_id` still open (queued, delivered, or
    /// resolved-but-unclaimed) as `(id, node_id, model_version)`,
    /// sorted by id — the wait set a resumed driver reconciles with.
    fn open_tasks(&self, _run_id: u64) -> Vec<(u64, u64, u64)> {
        Vec::new()
    }
}

/// Native execution: the SuperLink IS the grid — driver calls go
/// straight into the link's run/task state (Fig. 5a).
impl Grid for SuperLink {
    fn open_run(&self, run_id: u64) {
        self.register_run(run_id);
    }

    fn run_active(&self, run_id: u64) -> bool {
        SuperLink::run_active(self, run_id)
    }

    fn close_run(&self, run_id: u64) {
        self.finish(run_id);
    }

    fn node_ids(&self) -> Vec<u64> {
        self.nodes()
    }

    fn wait_for_nodes(&self, n: usize, timeout: Duration) -> anyhow::Result<Vec<u64>> {
        SuperLink::wait_for_nodes(self, n, timeout)
    }

    fn reap(&self) {
        self.reap_expired();
    }

    fn push_message(&self, msg: Message) -> u64 {
        let node = msg.metadata.dst_node_id;
        self.push_task(node, msg.into_ins())
    }

    fn pull_messages(&self, run_id: u64, ids: &[u64]) -> (Vec<Message>, Vec<(u64, String)>) {
        let (ready, failed) = self.poll_results(run_id, ids);
        (ready.into_iter().map(Message::from_res).collect(), failed)
    }

    fn wait_activity(&self, timeout: Duration) {
        SuperLink::wait_activity(self, timeout);
    }

    fn wait_activity_run(&self, run_id: u64, timeout: Duration) {
        SuperLink::wait_activity_run(self, run_id, timeout);
    }

    fn for_each_reply(
        &self,
        run_id: u64,
        ids: &[u64],
        timeout: Duration,
        policy: CompletionPolicy,
        f: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<RoundWait> {
        self.for_each_result_policy(run_id, ids, timeout, policy, |res| {
            f(Message::from_res(res))
        })
    }

    fn durable(&self) -> bool {
        self.is_durable()
    }

    fn checkpoint_due(&self, _run_id: u64) -> bool {
        SuperLink::checkpoint_due(self)
    }

    fn checkpoint_run(&self, run_id: u64, blob: Vec<u8>) {
        self.store_driver_checkpoint(run_id, blob);
    }

    fn driver_checkpoint(&self, run_id: u64) -> Option<Vec<u8>> {
        SuperLink::driver_checkpoint(self, run_id)
    }

    fn journal_fold(&self, run_id: u64, id: u64) {
        self.journal_async_fold(run_id, id);
    }

    fn journal_commit(&self, run_id: u64, version: u64) {
        self.journal_async_commit(run_id, version);
    }

    fn open_tasks(&self, run_id: u64) -> Vec<(u64, u64, u64)> {
        SuperLink::open_tasks(self, run_id)
    }
}

/// Shared handles delegate: `&Arc<SuperLink>` (and any `Arc<impl Grid>`)
/// drives rounds like the grid it wraps.
impl<G: Grid + ?Sized> Grid for Arc<G> {
    fn open_run(&self, run_id: u64) {
        (**self).open_run(run_id)
    }

    fn run_active(&self, run_id: u64) -> bool {
        (**self).run_active(run_id)
    }

    fn close_run(&self, run_id: u64) {
        (**self).close_run(run_id)
    }

    fn node_ids(&self) -> Vec<u64> {
        (**self).node_ids()
    }

    fn wait_for_nodes(&self, n: usize, timeout: Duration) -> anyhow::Result<Vec<u64>> {
        (**self).wait_for_nodes(n, timeout)
    }

    fn reap(&self) {
        (**self).reap()
    }

    fn push_message(&self, msg: Message) -> u64 {
        (**self).push_message(msg)
    }

    fn push_messages(&self, msgs: Vec<Message>) -> Vec<u64> {
        (**self).push_messages(msgs)
    }

    fn pull_messages(&self, run_id: u64, ids: &[u64]) -> (Vec<Message>, Vec<(u64, String)>) {
        (**self).pull_messages(run_id, ids)
    }

    fn wait_activity(&self, timeout: Duration) {
        (**self).wait_activity(timeout)
    }

    fn wait_activity_run(&self, run_id: u64, timeout: Duration) {
        (**self).wait_activity_run(run_id, timeout)
    }

    fn shard_count(&self) -> usize {
        (**self).shard_count()
    }

    fn for_each_reply(
        &self,
        run_id: u64,
        ids: &[u64],
        timeout: Duration,
        policy: CompletionPolicy,
        f: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<RoundWait> {
        (**self).for_each_reply(run_id, ids, timeout, policy, f)
    }

    fn durable(&self) -> bool {
        (**self).durable()
    }

    fn checkpoint_due(&self, run_id: u64) -> bool {
        (**self).checkpoint_due(run_id)
    }

    fn checkpoint_run(&self, run_id: u64, blob: Vec<u8>) {
        (**self).checkpoint_run(run_id, blob)
    }

    fn driver_checkpoint(&self, run_id: u64) -> Option<Vec<u8>> {
        (**self).driver_checkpoint(run_id)
    }

    fn journal_fold(&self, run_id: u64, id: u64) {
        (**self).journal_fold(run_id, id)
    }

    fn journal_commit(&self, run_id: u64, version: u64) {
        (**self).journal_commit(run_id, version)
    }

    fn open_tasks(&self, run_id: u64) -> Vec<(u64, u64, u64)> {
        (**self).open_tasks(run_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flower::message::{ConfigRecord, FlowerMsg, MessageType};
    use crate::flower::records::{ArrayRecord, RecordDict};

    fn join_node(link: &SuperLink) -> u64 {
        let reply = link.handle_frame(&FlowerMsg::CreateNode { requested: 0 }.encode());
        match FlowerMsg::decode(&reply).unwrap()
        {
            FlowerMsg::NodeCreated { node_id } => node_id,
            other => panic!("{other:?}"),
        }
    }

    fn answer_pull(link: &SuperLink, node_id: u64) -> Vec<crate::flower::message::TaskIns> {
        match FlowerMsg::decode(
            &link.handle_frame(&FlowerMsg::PullTaskIns { node_id }.encode()),
        )
        .unwrap()
        {
            FlowerMsg::TaskInsList { tasks, .. } => tasks,
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn superlink_grid_roundtrip_preserves_message_identity() {
        let link = SuperLink::new();
        let node = join_node(&link);
        link.open_run(7);
        assert!(Grid::run_active(&*link, 7));
        let msg = Message::train(
            node,
            ArrayRecord::from_flat(&[1.0, f32::NAN]),
            ConfigRecord::new(),
        )
        .for_round(7, 3)
        .with_model_version(5);
        let ids = link.push_messages(vec![msg]);
        // The node sees the same instruction the grid pushed.
        let tasks = answer_pull(&link, node);
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].task_id, ids[0]);
        assert_eq!(tasks[0].round, 3);
        assert_eq!(tasks[0].message_type, MessageType::Train);
        assert_eq!(tasks[0].model_version, 5);
        // It answers through the message surface.
        let ins = tasks.into_iter().next().unwrap();
        let reply = Message::from_ins(ins, node)
            .reply(RecordDict::from_arrays(ArrayRecord::from_flat(&[2.0])))
            .with_examples(10);
        link.handle_frame(&FlowerMsg::PushTaskRes { res: reply.into_res() }.encode());
        // The driver claims it as a Message with full metadata.
        let (replies, failed) = link.pull_messages(7, &ids);
        assert!(failed.is_empty());
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].metadata.src_node_id, node);
        assert_eq!(replies[0].metadata.message_id, ids[0]);
        assert_eq!(replies[0].metadata.num_examples, 10);
        // The SuperLink stamps the authoritative model version back.
        assert_eq!(replies[0].metadata.model_version, 5);
        assert_eq!(replies[0].content.arrays.to_flat(), vec![2.0]);
        link.close_run(7);
        assert!(!Grid::run_active(&*link, 7));
    }

    #[test]
    fn arc_blanket_impl_delegates() {
        let link = SuperLink::new();
        join_node(&link);
        // `Arc<SuperLink>` is itself a Grid (what `ServerApp::run(&link)`
        // relies on).
        fn takes_grid<G: Grid + ?Sized>(g: &G) -> Vec<u64> {
            g.open_run(1);
            g.node_ids()
        }
        assert_eq!(takes_grid(&link), vec![1]);
        let dyn_grid: &dyn Grid = &*link;
        assert_eq!(dyn_grid.node_ids(), vec![1]);
    }

    #[test]
    fn for_each_reply_streams_and_reports_policy_outcome() {
        let link = SuperLink::new();
        let node = join_node(&link);
        link.open_run(1);
        let ids = link.push_messages(vec![
            Message::query(node, ConfigRecord::new()).for_round(1, 1),
            Message::query(node, ConfigRecord::new()).for_round(1, 1),
        ]);
        // Answer only the first.
        let tasks = answer_pull(&link, node);
        let first = tasks.into_iter().next().unwrap();
        let reply = Message::from_ins(first, node).reply(RecordDict::default());
        link.handle_frame(&FlowerMsg::PushTaskRes { res: reply.into_res() }.encode());
        let mut seen = Vec::new();
        let wait = link
            .for_each_reply(
                1,
                &ids,
                Duration::from_millis(200),
                CompletionPolicy::quorum(1, Duration::from_millis(20)),
                &mut |m: Message| {
                    seen.push(m.metadata.message_id);
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(seen, vec![ids[0]]);
        assert_eq!(wait.completed, vec![ids[0]]);
        assert_eq!(wait.missing, vec![ids[1]]);
        link.close_run(1);
    }
}
