//! Federation-level configuration (the provisioning project file): site
//! names, transport choice, fault injection, compute threads. Parsed
//! from JSON by the CLI (`flarelink server/client/simulate`).

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct FederationConfig {
    pub project: String,
    pub sites: Vec<String>,
    /// TCP listen/dial address for provisioned deployments.
    pub server_addr: String,
    pub drop_prob: f64,
    pub latency_ms: u64,
    pub compute_threads: usize,
    /// Site pairs allowed to talk directly (P2P).
    pub direct_pairs: Vec<(String, String)>,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            project: "flarelink".into(),
            sites: vec!["site-1".into(), "site-2".into()],
            server_addr: "127.0.0.1:18411".into(),
            drop_prob: 0.0,
            latency_ms: 0,
            compute_threads: 1,
            direct_pairs: Vec::new(),
        }
    }
}

impl FederationConfig {
    pub fn from_json(j: &Json) -> FederationConfig {
        let d = FederationConfig::default();
        let sites = j
            .get("sites")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|s| s.as_str().map(|s| s.to_string()))
                    .collect::<Vec<_>>()
            })
            .filter(|v: &Vec<String>| !v.is_empty())
            .unwrap_or(d.sites.clone());
        let direct_pairs = j
            .get("direct_pairs")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|p| {
                        let pair = p.as_arr()?;
                        Some((
                            pair.first()?.as_str()?.to_string(),
                            pair.get(1)?.as_str()?.to_string(),
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default();
        FederationConfig {
            project: j.get("project").as_str().unwrap_or(&d.project).to_string(),
            sites,
            server_addr: j
                .get("server_addr")
                .as_str()
                .unwrap_or(&d.server_addr)
                .to_string(),
            drop_prob: j.get("drop_prob").as_f64().unwrap_or(d.drop_prob),
            latency_ms: j.get("latency_ms").as_u64().unwrap_or(d.latency_ms),
            compute_threads: j
                .get("compute_threads")
                .as_usize()
                .unwrap_or(d.compute_threads),
            direct_pairs,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("project", Json::str(self.project.clone())),
            (
                "sites",
                Json::Arr(self.sites.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            ("server_addr", Json::str(self.server_addr.clone())),
            ("drop_prob", Json::num(self.drop_prob)),
            ("latency_ms", Json::num(self.latency_ms as f64)),
            ("compute_threads", Json::num(self.compute_threads as f64)),
            (
                "direct_pairs",
                Json::Arr(
                    self.direct_pairs
                        .iter()
                        .map(|(a, b)| {
                            Json::Arr(vec![Json::str(a.clone()), Json::str(b.clone())])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<FederationConfig> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::from_json(&Json::parse(&text)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut cfg = FederationConfig::default();
        cfg.sites = vec!["a".into(), "b".into(), "c".into()];
        cfg.direct_pairs = vec![("a".into(), "b".into())];
        cfg.drop_prob = 0.25;
        let back = FederationConfig::from_json(&cfg.to_json());
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_for_empty_json() {
        let cfg = FederationConfig::from_json(&Json::parse("{}").unwrap());
        assert_eq!(cfg, FederationConfig::default());
    }
}
