//! # FlareLink
//!
//! Reproduction of *"Supercharging Federated Learning with Flower and
//! NVIDIA FLARE"* (CS.DC 2024) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * [`flare`] — the FLARE-analogue runtime: multi-job SCP/CCP control
//!   plane, reliable messaging, provisioning, authz, metric streaming;
//! * [`flower`] — the Flower-analogue FL framework: SuperLink/SuperNode,
//!   ServerApp strategies, ClientApps;
//! * [`bridge`] — the paper's contribution: LGS/LGC routing of Flower
//!   traffic through FLARE, unmodified apps on both ends;
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); Python never runs at request time;
//! * [`train`] — synthetic federated datasets + the local trainer that
//!   drives the artifacts;
//! * [`transport`], [`proto`], [`util`], [`telemetry`], [`config`] —
//!   substrates built from scratch for the offline environment.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod bridge;
pub mod config;
pub mod flare;
pub mod harness;
pub mod flower;
pub mod proto;
pub mod runtime;
pub mod telemetry;
pub mod train;
pub mod transport;
pub mod util;
