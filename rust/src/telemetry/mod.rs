//! Telemetry substrate: leveled logging (the `log` crate facade with our
//! own sink) and a process-wide counter registry used by the SCP/CCP and
//! the bench harness to report routing/retry/byte counts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Mutex, Once};

// ---------------------------------------------------------------------------
// Logging
// ---------------------------------------------------------------------------

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `FLARELINK_LOG` (error|warn|info|
/// debug|trace), default `warn` so tests/benches stay quiet.
pub fn init_logging() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let level = match std::env::var("FLARELINK_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("info") => log::LevelFilter::Info,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            Ok("off") => log::LevelFilter::Off,
            _ => log::LevelFilter::Warn,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

// ---------------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------------

static COUNTERS: Mutex<BTreeMap<String, &'static AtomicI64>> = Mutex::new(BTreeMap::new());

/// Fetch-or-create a named process-wide counter. The returned reference is
/// 'static (counters are never dropped), so hot paths can cache it.
pub fn counter(name: &str) -> &'static AtomicI64 {
    let mut map = COUNTERS.lock().unwrap();
    if let Some(c) = map.get(name) {
        return c;
    }
    let c: &'static AtomicI64 = Box::leak(Box::new(AtomicI64::new(0)));
    map.insert(name.to_string(), c);
    c
}

pub fn bump(name: &str, delta: i64) {
    counter(name).fetch_add(delta, Ordering::Relaxed);
}

/// Snapshot of all counters (sorted by name).
pub fn snapshot() -> Vec<(String, i64)> {
    COUNTERS
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
        .collect()
}

/// Log every non-zero counter at INFO, one line per counter, under the
/// given heading. No-op unless INFO logging is enabled (set
/// `FLARELINK_LOG=info`), so tests and benches stay quiet by default.
/// Used at Federation teardown to surface the durability counters
/// (`wal.appends`, `wal.bytes`, `checkpoint.count`,
/// `recovery.replayed_records`, ...) without a metrics stack.
pub fn dump_counters(heading: &str) {
    if !log::log_enabled!(log::Level::Info) {
        return;
    }
    log::info!("{heading}: counter snapshot");
    for (name, value) in snapshot() {
        if value != 0 {
            log::info!("{heading}:   {name} = {value}");
        }
    }
}

/// Reset all counters to zero (bench harness runs).
pub fn reset_counters() {
    for (_, v) in COUNTERS.lock().unwrap().iter() {
        v.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        bump("test.a", 2);
        bump("test.a", 3);
        bump("test.b", 1);
        let snap: BTreeMap<String, i64> = snapshot().into_iter().collect();
        assert!(snap["test.a"] >= 5);
        assert!(snap["test.b"] >= 1);
    }

    #[test]
    fn counter_identity_is_stable() {
        let a = counter("test.identity") as *const _;
        let b = counter("test.identity") as *const _;
        assert_eq!(a, b);
    }

    #[test]
    fn reset_zeroes() {
        bump("test.reset", 7);
        reset_counters();
        assert_eq!(counter("test.reset").load(Ordering::Relaxed), 0);
    }
}
