//! Telemetry substrate: leveled logging (the `log` crate facade with our
//! own sink) and a process-wide counter registry used by the SCP/CCP and
//! the bench harness to report routing/retry/byte counts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Mutex, Once};

// ---------------------------------------------------------------------------
// Logging
// ---------------------------------------------------------------------------

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `FLARELINK_LOG` (error|warn|info|
/// debug|trace), default `warn` so tests/benches stay quiet.
pub fn init_logging() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let level = match std::env::var("FLARELINK_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("info") => log::LevelFilter::Info,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            Ok("off") => log::LevelFilter::Off,
            _ => log::LevelFilter::Warn,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

// ---------------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------------

static COUNTERS: Mutex<BTreeMap<String, &'static AtomicI64>> = Mutex::new(BTreeMap::new());

/// Fetch-or-create a named process-wide counter. The returned reference is
/// 'static (counters are never dropped), so hot paths can cache it.
pub fn counter(name: &str) -> &'static AtomicI64 {
    let mut map = COUNTERS.lock().unwrap();
    if let Some(c) = map.get(name) {
        return c;
    }
    let c: &'static AtomicI64 = Box::leak(Box::new(AtomicI64::new(0)));
    map.insert(name.to_string(), c);
    c
}

pub fn bump(name: &str, delta: i64) {
    counter(name).fetch_add(delta, Ordering::Relaxed);
}

/// A per-instance counter scope: a label (e.g. `shard-2`) that
/// attributes every bump to one component instance while STILL feeding
/// the process-wide total of the same name atomically. Concurrent link
/// instances — the interior shards of a
/// [`crate::flower::shard::ShardedGrid`] — each hold their own scope, so
/// a sharded run reports both true totals (the unlabelled counter, a
/// single `fetch_add` target shared by every instance) and a per-shard
/// breakdown (`name[label]` entries), aggregated and printed together by
/// [`dump_counters`] at `Federation` teardown.
///
/// An empty label is the plain global scope: bumps touch only the
/// unlabelled counter, exactly like [`bump`].
#[derive(Clone, Debug, Default)]
pub struct Counters {
    label: String,
}

impl Counters {
    /// The unlabelled (process-global) scope.
    pub fn global() -> Counters {
        Counters {
            label: String::new(),
        }
    }

    /// A labelled instance scope.
    pub fn labelled(label: impl Into<String>) -> Counters {
        Counters {
            label: label.into(),
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Bump `name` under this instance's label AND the process-wide
    /// total of the same name. Both are atomic adds on leaked statics,
    /// so concurrent instances never lose counts to each other.
    pub fn bump(&self, name: &str, delta: i64) {
        bump(name, delta);
        if !self.label.is_empty() {
            bump(&format!("{name}[{}]", self.label), delta);
        }
    }
}

/// Snapshot of all counters (sorted by name). Labelled instance entries
/// (`name[label]`) sort directly after their unlabelled total.
pub fn snapshot() -> Vec<(String, i64)> {
    COUNTERS
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
        .collect()
}

/// Per-instance totals: every labelled counter (`name[label]`) summed
/// by base name — the cross-check that instance attribution accounts
/// for the whole total. Sorted by base name.
pub fn instance_totals() -> Vec<(String, i64)> {
    let mut totals: BTreeMap<String, i64> = BTreeMap::new();
    for (name, value) in snapshot() {
        if let Some(base) = name.strip_suffix(']').and_then(|s| s.split_once('[')) {
            *totals.entry(base.0.to_string()).or_insert(0) += value;
        }
    }
    totals.into_iter().collect()
}

/// Log every non-zero counter at INFO, one line per counter, under the
/// given heading. Labelled instance entries (`name[label]`, e.g. the
/// per-shard breakdown of a sharded link) print indented beneath their
/// unlabelled total, which is the authoritative aggregate. No-op unless
/// INFO logging is enabled (set `FLARELINK_LOG=info`), so tests and
/// benches stay quiet by default. Used at `Federation` teardown to
/// surface the durability counters (`wal.appends`, `wal.bytes`,
/// `checkpoint.count`, `recovery.replayed_records`, ...) without a
/// metrics stack.
pub fn dump_counters(heading: &str) {
    if !log::log_enabled!(log::Level::Info) {
        return;
    }
    log::info!("{heading}: counter snapshot");
    for (name, value) in snapshot() {
        if value != 0 {
            if name.ends_with(']') && name.contains('[') {
                log::info!("{heading}:     {name} = {value}");
            } else {
                log::info!("{heading}:   {name} = {value}");
            }
        }
    }
}

/// Reset all counters to zero (bench harness runs).
pub fn reset_counters() {
    for (_, v) in COUNTERS.lock().unwrap().iter() {
        v.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        bump("test.a", 2);
        bump("test.a", 3);
        bump("test.b", 1);
        let snap: BTreeMap<String, i64> = snapshot().into_iter().collect();
        assert!(snap["test.a"] >= 5);
        assert!(snap["test.b"] >= 1);
    }

    #[test]
    fn counter_identity_is_stable() {
        let a = counter("test.identity") as *const _;
        let b = counter("test.identity") as *const _;
        assert_eq!(a, b);
    }

    #[test]
    fn reset_zeroes() {
        bump("test.reset", 7);
        reset_counters();
        assert_eq!(counter("test.reset").load(Ordering::Relaxed), 0);
    }

    #[test]
    fn labelled_scope_feeds_instance_and_total() {
        let a = Counters::labelled("inst-a");
        let b = Counters::labelled("inst-b");
        let total0 = counter("test.labelled").load(Ordering::Relaxed);
        a.bump("test.labelled", 2);
        b.bump("test.labelled", 3);
        b.bump("test.labelled", 1);
        // The unlabelled counter is the true total across instances.
        assert_eq!(
            counter("test.labelled").load(Ordering::Relaxed),
            total0 + 6
        );
        let snap: BTreeMap<String, i64> = snapshot().into_iter().collect();
        assert_eq!(snap["test.labelled[inst-a]"], 2);
        assert_eq!(snap["test.labelled[inst-b]"], 4);
        // Instance totals re-derive the aggregate from the breakdown.
        let totals: BTreeMap<String, i64> = instance_totals().into_iter().collect();
        assert_eq!(totals["test.labelled"], 6);
    }

    #[test]
    fn global_scope_leaves_no_labelled_entries() {
        Counters::global().bump("test.globalscope", 5);
        assert!(snapshot()
            .iter()
            .all(|(n, _)| !n.starts_with("test.globalscope[")));
    }
}
