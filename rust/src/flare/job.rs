//! Job model: specs submitted to the SCP, runtime status, and the context
//! handed to app runners on both server and client sides (§3.1 "Job
//! Network" — one ephemeral network of `<site>:<job_id>` cells per job).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::flare::reliable::Messenger;
use crate::flare::tracking::SummaryWriter;
use crate::util::json::Json;

pub type JobId = String;

/// What the submitter hands the SCP (FLARE's `nvflare job submit`).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: JobId,
    /// App type key resolved by the [`AppFactory`] on each site
    /// (e.g. "echo", "flower_bridge").
    pub app: String,
    /// Arbitrary app config (forwarded verbatim to every runner).
    pub config: Json,
    /// Sites the job must run on; empty = all registered sites.
    pub sites: Vec<String>,
    /// Resource slots consumed on each participating site while running.
    pub resources_per_site: u32,
}

impl JobSpec {
    pub fn new(id: &str, app: &str) -> Self {
        Self {
            id: id.to_string(),
            app: app.to_string(),
            config: Json::Obj(BTreeMap::new()),
            sites: Vec::new(),
            resources_per_site: 1,
        }
    }

    pub fn with_config(mut self, config: Json) -> Self {
        self.config = config;
        self
    }

    pub fn with_sites(mut self, sites: &[&str]) -> Self {
        self.sites = sites.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = crate::util::bytes::Writer::new();
        w.str(&self.id);
        w.str(&self.app);
        w.str(&self.config.to_string());
        w.u32(self.sites.len() as u32);
        for s in &self.sites {
            w.str(s);
        }
        w.u32(self.resources_per_site);
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<JobSpec> {
        let mut r = crate::util::bytes::Reader::new(buf);
        let id = r.str()?.to_string();
        let app = r.str()?.to_string();
        let config = Json::parse(r.str()?)?;
        let n = r.u32()? as usize;
        let mut sites = Vec::with_capacity(n);
        for _ in 0..n {
            sites.push(r.str()?.to_string());
        }
        let resources_per_site = r.u32()?;
        Ok(JobSpec {
            id,
            app,
            config,
            sites,
            resources_per_site,
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Accepted, waiting for resources.
    Queued,
    /// Deploy requests sent, job network forming.
    Deploying,
    Running,
    Finished,
    Failed,
    Aborted,
}

impl JobStatus {
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobStatus::Finished | JobStatus::Failed | JobStatus::Aborted
        )
    }

    pub fn as_str(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Deploying => "deploying",
            JobStatus::Running => "running",
            JobStatus::Finished => "finished",
            JobStatus::Failed => "failed",
            JobStatus::Aborted => "aborted",
        }
    }
}

/// Everything an app runner can touch. One per (job, site) — and one on
/// the server with `site == "server"`.
pub struct JobCtx {
    pub job_id: JobId,
    /// This runner's site name ("server" for the server-side runner).
    pub site: String,
    /// Sites participating in this job (sorted; excludes "server").
    pub participants: Vec<String>,
    /// The job cell's reliable messenger (address `<site>:<job_id>`).
    pub messenger: Arc<Messenger>,
    pub config: Json,
    /// FLARE experiment-tracking writer (§5.2) — streams to the SCP.
    pub tracker: SummaryWriter,
    /// Compute service handle for PJRT execution (None in pure-routing
    /// jobs/tests).
    pub compute: Option<crate::runtime::ComputeHandle>,
    /// This site's startup-kit token (empty on the server-side runner):
    /// bridged apps present it with every relayed frame so the server
    /// job cell can refuse traffic from unprovisioned sites.
    pub site_token: String,
    /// Server-side only: the project authorizer used to verify site
    /// credentials on incoming bridged frames (None on client runners
    /// and in raw-messenger tests, which skips the check).
    pub authenticator: Option<Arc<crate::flare::auth::Authorizer>>,
    /// Cooperative abort flag: set when the SCP aborts the job; runners
    /// should poll it at round boundaries.
    pub abort: std::sync::Arc<std::sync::atomic::AtomicBool>,
}

impl JobCtx {
    pub fn aborted(&self) -> bool {
        self.abort.load(std::sync::atomic::Ordering::Acquire)
    }
}

/// Per-site factory resolving a [`JobSpec::app`] key to runnable code.
/// Returning `Err` fails the deployment (surfaces at the SCP).
pub trait AppFactory: Send + Sync {
    /// Run the client-side app for this job; blocks until done.
    fn run_client(&self, ctx: JobCtx) -> anyhow::Result<()>;
    /// Run the server-side app; its return resolves the whole job.
    fn run_server(&self, ctx: JobCtx) -> anyhow::Result<()>;
    /// App keys this factory can run.
    fn supports(&self, app: &str) -> bool;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip() {
        let spec = JobSpec::new("job-1", "flower_bridge")
            .with_config(Json::obj(vec![("rounds", Json::num(3))]))
            .with_sites(&["site-1", "site-2"]);
        let back = JobSpec::decode(&spec.encode()).unwrap();
        assert_eq!(back.id, "job-1");
        assert_eq!(back.app, "flower_bridge");
        assert_eq!(back.config.get("rounds").as_u64(), Some(3));
        assert_eq!(back.sites, vec!["site-1", "site-2"]);
        assert_eq!(back.resources_per_site, 1);
    }

    #[test]
    fn status_terminality() {
        assert!(!JobStatus::Queued.is_terminal());
        assert!(!JobStatus::Running.is_terminal());
        assert!(JobStatus::Finished.is_terminal());
        assert!(JobStatus::Aborted.is_terminal());
        assert!(JobStatus::Failed.is_terminal());
    }
}
