//! Client Control Process (paper §3.1 / Fig. 2): one per site. Registers
//! with the SCP using its startup-kit token, heartbeats, receives job
//! deploy/stop commands, and runs per-job client app workers (the site's
//! members of each "Job Network").

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::flare::fabric::{CcpFabric, Fabric};
use crate::flare::job::{AppFactory, JobCtx, JobId, JobSpec};
use crate::flare::provision::StartupKit;
use crate::flare::reliable::{Messenger, RetryPolicy};
use crate::flare::scp::topics;
use crate::flare::tracking::SummaryWriter;
use crate::proto::{address, Envelope};
use crate::util::bytes::{Reader, Writer};

#[derive(Clone, Debug)]
pub struct CcpConfig {
    /// Resource slots this site offers (0 = accept server default).
    pub slots: u32,
    pub heartbeat_interval: Duration,
    pub policy: RetryPolicy,
}

impl Default for CcpConfig {
    fn default() -> Self {
        Self {
            slots: 0,
            heartbeat_interval: Duration::from_millis(500),
            policy: RetryPolicy::default(),
        }
    }
}

struct ClientJob {
    abort: Arc<AtomicBool>,
    messenger: Arc<Messenger>,
}

pub struct Ccp {
    site: String,
    /// Startup-kit token — handed to job runners so bridged apps can
    /// present the site credential on relayed frames.
    token: String,
    pub fabric: Arc<CcpFabric>,
    control: Arc<Messenger>,
    app_factory: Arc<dyn AppFactory>,
    compute: Option<crate::runtime::ComputeHandle>,
    cfg: CcpConfig,
    jobs: Mutex<HashMap<JobId, ClientJob>>,
    shutdown: Arc<AtomicBool>,
}

impl Ccp {
    /// Start the CCP: register with the SCP (authenticating with the
    /// startup kit) and begin serving deploy/stop commands.
    pub fn start(
        fabric: Arc<CcpFabric>,
        kit: &StartupKit,
        app_factory: Arc<dyn AppFactory>,
        compute: Option<crate::runtime::ComputeHandle>,
        cfg: CcpConfig,
    ) -> anyhow::Result<Arc<Ccp>> {
        let site = kit.name.clone();
        let control = Messenger::spawn(fabric.clone() as Arc<dyn Fabric>, &site)?;
        let ccp = Arc::new(Ccp {
            site: site.clone(),
            token: kit.token.clone(),
            fabric,
            control: control.clone(),
            app_factory,
            compute,
            cfg: cfg.clone(),
            jobs: Mutex::new(HashMap::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
        });

        let me = ccp.clone();
        control.set_handler(Arc::new(move |env| me.handle_control(env)));

        // Register (reliable; SCP may still be coming up).
        let mut w = Writer::new();
        w.str(&site);
        w.str(&kit.token);
        w.u32(cfg.slots);
        let rep = control.request(address::SERVER, topics::REGISTER, w.into_bytes(), cfg.policy)?;
        if rep.payload != b"ok" {
            anyhow::bail!("registration refused: {:?}", rep.payload);
        }
        log::info!("{site}: registered with SCP");

        // Heartbeat loop.
        let me = ccp.clone();
        std::thread::Builder::new()
            .name(format!("ccp-hb-{site}"))
            .spawn(move || {
                while !me.shutdown.load(Ordering::Acquire) {
                    me.control
                        .fire_event(address::SERVER, topics::HEARTBEAT, Vec::new());
                    std::thread::sleep(me.cfg.heartbeat_interval);
                }
            })?;
        Ok(ccp)
    }

    pub fn site(&self) -> &str {
        &self.site
    }

    pub fn running_jobs(&self) -> Vec<JobId> {
        let mut v: Vec<JobId> = self.jobs.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for (_, job) in self.jobs.lock().unwrap().iter() {
            job.abort.store(true, Ordering::Release);
            job.messenger.shutdown();
        }
        self.control.shutdown();
        self.fabric.shutdown();
    }

    fn handle_control(self: &Arc<Self>, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        match env.topic.as_str() {
            topics::DEPLOY => self.on_deploy(env),
            topics::STOP => self.on_stop(env),
            other => anyhow::bail!("ccp {}: unknown control topic '{other}'", self.site),
        }
    }

    fn on_deploy(self: &Arc<Self>, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        let mut r = Reader::new(&env.payload);
        let spec = JobSpec::decode(r.bytes()?)?;
        let mut pr = Reader::new(r.bytes()?);
        let n = pr.u32()? as usize;
        let mut participants = Vec::with_capacity(n);
        for _ in 0..n {
            participants.push(pr.str()?.to_string());
        }

        let job_id = spec.id.clone();
        {
            let jobs = self.jobs.lock().unwrap();
            if jobs.contains_key(&job_id) {
                return Ok(b"already-deployed".to_vec()); // dedup across retries
            }
        }
        let cell = address::job_cell(&self.site, &job_id);
        let messenger = Messenger::spawn(self.fabric.clone() as Arc<dyn Fabric>, &cell)?;
        let abort = Arc::new(AtomicBool::new(false));
        self.jobs.lock().unwrap().insert(
            job_id.clone(),
            ClientJob {
                abort: abort.clone(),
                messenger: messenger.clone(),
            },
        );

        let ctx = JobCtx {
            job_id: job_id.clone(),
            site: self.site.clone(),
            participants,
            messenger: messenger.clone(),
            config: spec.config.clone(),
            tracker: SummaryWriter::new(messenger.clone(), &job_id, &self.site),
            compute: self.compute.clone(),
            site_token: self.token.clone(),
            authenticator: None,
            abort,
        };
        let me = self.clone();
        std::thread::Builder::new()
            .name(format!("job-{}-{}", self.site, job_id))
            .spawn(move || {
                let result = me.app_factory.run_client(ctx);
                // Report completion to the SCP (best-effort).
                let mut w = Writer::new();
                w.str(&job_id);
                w.str(&me.site);
                match &result {
                    Ok(()) => {
                        w.u8(1);
                        w.str("");
                    }
                    Err(e) => {
                        w.u8(0);
                        w.str(&e.to_string());
                        log::error!("{}: job {job_id} client failed: {e}", me.site);
                    }
                }
                let _ = me.control.request(
                    address::SERVER,
                    topics::SITE_DONE,
                    w.into_bytes(),
                    RetryPolicy {
                        deadline: Duration::from_secs(2),
                        ..me.cfg.policy
                    },
                );
                if let Some(job) = me.jobs.lock().unwrap().remove(&job_id) {
                    job.messenger.shutdown();
                }
            })?;
        Ok(b"ok".to_vec())
    }

    fn on_stop(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        let job_id = std::str::from_utf8(&env.payload)?;
        if let Some(job) = self.jobs.lock().unwrap().get(job_id) {
            job.abort.store(true, Ordering::Release);
        }
        Ok(b"ok".to_vec())
    }
}
