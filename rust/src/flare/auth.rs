//! Authentication + authorization (paper §2: "User authentication and
//! authorization mechanisms enhance security and access control").
//!
//! Authentication: verify startup-kit tokens via the [`Provisioner`].
//! Authorization: a per-role action policy table, configurable, checked
//! by the SCP on every admin/control operation.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::flare::provision::{Provisioner, Role};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Action {
    RegisterSite,
    SubmitJob,
    AbortJob,
    ListJobs,
    StreamMetrics,
    /// Ship custom app code with a job (paper: "deployment of custom code").
    DeployCustomCode,
}

#[derive(Debug)]
pub enum AuthError {
    BadToken(String),
    Denied { role: Role, action: Action },
    Unknown(String),
}

impl std::fmt::Display for AuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AuthError::BadToken(who) => write!(f, "auth: invalid token for '{who}'"),
            AuthError::Denied { role, action } => {
                write!(f, "auth: role {role:?} not permitted to {action:?}")
            }
            AuthError::Unknown(who) => write!(f, "auth: unknown principal '{who}'"),
        }
    }
}

impl std::error::Error for AuthError {}

/// Default policy mirroring FLARE's stock authorization:
/// admins run jobs, sites participate and stream, nobody else does anything.
fn default_policy() -> HashMap<(Role, Action), bool> {
    use Action::*;
    use Role::*;
    let mut p = HashMap::new();
    for (role, action, allow) in [
        (Site, RegisterSite, true),
        (Site, StreamMetrics, true),
        (Site, SubmitJob, false),
        (Site, AbortJob, false),
        (Site, ListJobs, false),
        (Site, DeployCustomCode, false),
        (Admin, RegisterSite, false),
        (Admin, SubmitJob, true),
        (Admin, AbortJob, true),
        (Admin, ListJobs, true),
        (Admin, DeployCustomCode, true),
        (Admin, StreamMetrics, false),
        (Server, RegisterSite, false),
        (Server, SubmitJob, true), // server-local CLI acts as admin
        (Server, AbortJob, true),
        (Server, ListJobs, true),
        (Server, StreamMetrics, true),
        (Server, DeployCustomCode, true),
    ] {
        p.insert((role, action), allow);
    }
    p
}

/// A verified identity.
#[derive(Clone, Debug)]
pub struct Principal {
    pub name: String,
    pub role: Role,
}

pub struct Authorizer {
    provisioner: Provisioner,
    policy: HashMap<(Role, Action), bool>,
    /// Authenticated principals (site registrations).
    sessions: Mutex<HashMap<String, Principal>>,
}

impl Authorizer {
    pub fn new(provisioner: Provisioner) -> Self {
        Self {
            provisioner,
            policy: default_policy(),
            sessions: Mutex::new(HashMap::new()),
        }
    }

    /// Override one policy entry (config-driven deployments).
    pub fn set_policy(&mut self, role: Role, action: Action, allow: bool) {
        self.policy.insert((role, action), allow);
    }

    /// Authenticate a presented token; on success the principal is
    /// session-cached so later calls can use [`check`].
    pub fn authenticate(&self, name: &str, role: Role, token: &str) -> Result<Principal, AuthError> {
        if !self.provisioner.verify(name, role, token) {
            return Err(AuthError::BadToken(name.to_string()));
        }
        let p = Principal {
            name: name.to_string(),
            role,
        };
        self.sessions
            .lock()
            .unwrap()
            .insert(name.to_string(), p.clone());
        Ok(p)
    }

    /// Authorize an action for an authenticated principal by name.
    pub fn check(&self, name: &str, action: Action) -> Result<(), AuthError> {
        let sessions = self.sessions.lock().unwrap();
        let p = sessions
            .get(name)
            .ok_or_else(|| AuthError::Unknown(name.to_string()))?;
        self.check_role(p.role, action)
    }

    pub fn check_role(&self, role: Role, action: Action) -> Result<(), AuthError> {
        if *self.policy.get(&(role, action)).unwrap_or(&false) {
            Ok(())
        } else {
            Err(AuthError::Denied { role, action })
        }
    }

    pub fn is_authenticated(&self, name: &str) -> bool {
        self.sessions.lock().unwrap().contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn authz() -> Authorizer {
        Authorizer::new(Provisioner::new("proj", b"secret"))
    }

    #[test]
    fn authenticate_then_authorize() {
        let a = authz();
        let p = Provisioner::new("proj", b"secret");
        let kit = p.provision("site-1", Role::Site, "");
        a.authenticate("site-1", Role::Site, &kit.token).unwrap();
        assert!(a.is_authenticated("site-1"));
        a.check("site-1", Action::RegisterSite).unwrap();
        a.check("site-1", Action::StreamMetrics).unwrap();
        assert!(matches!(
            a.check("site-1", Action::SubmitJob),
            Err(AuthError::Denied { .. })
        ));
    }

    #[test]
    fn bad_token_rejected() {
        let a = authz();
        assert!(matches!(
            a.authenticate("site-1", Role::Site, "ff00"),
            Err(AuthError::BadToken(_))
        ));
        assert!(!a.is_authenticated("site-1"));
    }

    #[test]
    fn unknown_principal_rejected() {
        let a = authz();
        assert!(matches!(
            a.check("ghost", Action::ListJobs),
            Err(AuthError::Unknown(_))
        ));
    }

    #[test]
    fn admin_can_manage_jobs() {
        let a = authz();
        let p = Provisioner::new("proj", b"secret");
        let kit = p.provision("ops", Role::Admin, "");
        a.authenticate("ops", Role::Admin, &kit.token).unwrap();
        a.check("ops", Action::SubmitJob).unwrap();
        a.check("ops", Action::AbortJob).unwrap();
        a.check("ops", Action::ListJobs).unwrap();
        a.check("ops", Action::DeployCustomCode).unwrap();
    }

    #[test]
    fn policy_override() {
        let mut a = authz();
        a.set_policy(Role::Site, Action::SubmitJob, true);
        a.check_role(Role::Site, Action::SubmitJob).unwrap();
    }

    #[test]
    fn role_cannot_be_escalated_by_token_swap() {
        // A site kit presented with role=Admin must fail (role is inside
        // the MAC).
        let a = authz();
        let p = Provisioner::new("proj", b"secret");
        let kit = p.provision("site-1", Role::Site, "");
        assert!(a.authenticate("site-1", Role::Admin, &kit.token).is_err());
    }
}
