//! ReliableMessage (paper §4.1), implemented exactly as described:
//!
//! 1. The requester sends the request; if the send fails (or is lost —
//!    the transport may drop silently), it retries until the peer
//!    acknowledges or the total deadline passes (job aborts).
//! 2. Once acknowledged, the requester waits for the response. The peer
//!    pushes the result when processing finishes; *concurrently* the
//!    requester polls with Query messages. The result is accepted from
//!    whichever path delivers first — push (Reply to the request) or
//!    pull (Reply to a Query).
//!
//! The receiving side deduplicates retried requests (at-most-once handler
//! execution) and caches results so Queries and duplicate requests can be
//! answered without re-execution.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::flare::fabric::{next_msg_id, Fabric, Mailbox};
use crate::proto::{Envelope, MsgKind};
use crate::telemetry;

/// Retry/poll/deadline knobs (paper: "a moment later", "maximum amount of
/// time has passed").
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Wait for an Ack/Reply after each send attempt before re-sending.
    pub per_try: Duration,
    /// Interval between Query polls while waiting for the result.
    pub query_interval: Duration,
    /// Total time budget; exceeding it returns `ReliableError::Deadline`
    /// (which aborts the job at the layer above, as in the paper).
    pub deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            per_try: Duration::from_millis(100),
            query_interval: Duration::from_millis(100),
            deadline: Duration::from_secs(30),
        }
    }
}

impl RetryPolicy {
    /// Fast policy for tests/benches on lossy in-proc transports.
    pub fn fast() -> Self {
        Self {
            per_try: Duration::from_millis(10),
            query_interval: Duration::from_millis(10),
            deadline: Duration::from_secs(5),
        }
    }
}

#[derive(Debug)]
pub enum ReliableError {
    Deadline { peer: String, phase: &'static str },
    Shutdown,
    Fabric(crate::flare::fabric::FabricError),
    Remote(String),
}

impl std::fmt::Display for ReliableError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReliableError::Deadline { peer, phase } => {
                write!(f, "reliable: deadline exceeded waiting for {peer} ({phase})")
            }
            ReliableError::Shutdown => write!(f, "reliable: messenger shut down"),
            ReliableError::Fabric(e) => write!(f, "reliable: fabric: {e}"),
            ReliableError::Remote(msg) => write!(f, "reliable: remote handler error: {msg}"),
        }
    }
}

impl std::error::Error for ReliableError {}

impl From<crate::flare::fabric::FabricError> for ReliableError {
    fn from(e: crate::flare::fabric::FabricError) -> Self {
        ReliableError::Fabric(e)
    }
}

/// Handler for incoming requests: payload-in, payload-out. The envelope
/// is handed over mutably so handlers can `std::mem::take` the owned
/// payload instead of copying it (the bridge's zero-copy LGC hop).
pub type Handler = Arc<dyn Fn(&mut Envelope) -> anyhow::Result<Vec<u8>> + Send + Sync>;
/// Handler for fire-and-forget events.
pub type EventHandler = Arc<dyn Fn(&Envelope) + Send + Sync>;

enum WaiterMsg {
    Acked,
    Reply(Envelope),
}

/// Result cache bounded by entry count; evicts oldest.
struct ResultCache {
    map: HashMap<(String, u64), Envelope>,
    order: VecDeque<(String, u64)>,
    cap: usize,
}

impl ResultCache {
    fn new(cap: usize) -> Self {
        Self {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap,
        }
    }

    fn insert(&mut self, key: (String, u64), value: Envelope) {
        if self.map.insert(key.clone(), value).is_none() {
            self.order.push_back(key);
            while self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }

    fn get(&self, key: &(String, u64)) -> Option<&Envelope> {
        self.map.get(key)
    }
}

/// A cell with reliable request/response semantics on top of a [`Fabric`].
pub struct Messenger {
    address: String,
    fabric: Arc<dyn Fabric>,
    waiters: Mutex<HashMap<u64, Sender<WaiterMsg>>>,
    results: Mutex<ResultCache>,
    inflight: Mutex<HashSet<(String, u64)>>,
    handler: RwLock<Option<Handler>>,
    event_handler: RwLock<Option<EventHandler>>,
    shutdown: Arc<AtomicBool>,
}

impl Messenger {
    /// Register cell `address` on `fabric` and start its service loop.
    pub fn spawn(fabric: Arc<dyn Fabric>, address: &str) -> anyhow::Result<Arc<Messenger>> {
        let mailbox = fabric.register(address)?;
        let m = Arc::new(Messenger {
            address: address.to_string(),
            fabric,
            waiters: Mutex::new(HashMap::new()),
            results: Mutex::new(ResultCache::new(4096)),
            inflight: Mutex::new(HashSet::new()),
            handler: RwLock::new(None),
            event_handler: RwLock::new(None),
            shutdown: Arc::new(AtomicBool::new(false)),
        });
        let svc = m.clone();
        std::thread::Builder::new()
            .name(format!("msgr-{address}"))
            .spawn(move || svc.service_loop(mailbox))?;
        Ok(m)
    }

    pub fn address(&self) -> &str {
        &self.address
    }

    /// Install the request handler (must be set before peers call in).
    pub fn set_handler(&self, h: Handler) {
        *self.handler.write().unwrap() = Some(h);
    }

    pub fn set_event_handler(&self, h: EventHandler) {
        *self.event_handler.write().unwrap() = Some(h);
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.fabric.unregister(&self.address);
    }

    // ---------------- requester side ----------------

    /// Reliable request/response per §4.1. Returns the reply envelope.
    pub fn request(
        &self,
        destination: &str,
        topic: &str,
        payload: Vec<u8>,
        policy: RetryPolicy,
    ) -> Result<Envelope, ReliableError> {
        self.request_with_headers(destination, topic, payload, Vec::new(), policy)
    }

    /// [`request`] with string headers attached (e.g. admin credentials).
    pub fn request_with_headers(
        &self,
        destination: &str,
        topic: &str,
        payload: Vec<u8>,
        headers: Vec<(String, String)>,
        policy: RetryPolicy,
    ) -> Result<Envelope, ReliableError> {
        let id = next_msg_id();
        let mut env = Envelope::new(MsgKind::Request, &self.address, destination, topic);
        env.id = id;
        env.payload = payload;
        env.headers = headers;

        let (tx, rx) = channel::<WaiterMsg>();
        self.waiters.lock().unwrap().insert(id, tx);
        let _cleanup = WaiterGuard { m: self, id };

        let deadline = Instant::now() + policy.deadline;

        // Phase 1: send until acked (or replied — replies also prove receipt).
        let mut acked = false;
        while !acked {
            if self.shutdown.load(Ordering::Acquire) {
                return Err(ReliableError::Shutdown);
            }
            if Instant::now() >= deadline {
                telemetry::bump("reliable.deadline", 1);
                return Err(ReliableError::Deadline {
                    peer: destination.to_string(),
                    phase: "send",
                });
            }
            telemetry::bump("reliable.send_attempts", 1);
            // A failed fabric send (no route yet, link down) is treated
            // like a lost frame: retry after per_try.
            let _ = self.fabric.send(env.clone());
            match rx.recv_timeout(policy.per_try) {
                Ok(WaiterMsg::Acked) => acked = true,
                Ok(WaiterMsg::Reply(rep)) => return finish(rep),
                Err(_) => {} // retry
            }
        }

        // Phase 2: wait for push; poll with Query in parallel.
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return Err(ReliableError::Shutdown);
            }
            let now = Instant::now();
            if now >= deadline {
                telemetry::bump("reliable.deadline", 1);
                return Err(ReliableError::Deadline {
                    peer: destination.to_string(),
                    phase: "result",
                });
            }
            let wait = policy.query_interval.min(deadline - now);
            match rx.recv_timeout(wait) {
                Ok(WaiterMsg::Reply(rep)) => return finish(rep),
                Ok(WaiterMsg::Acked) => continue,
                Err(_) => {
                    // Poll: "is the result ready?"
                    telemetry::bump("reliable.queries", 1);
                    let mut q =
                        Envelope::new(MsgKind::Query, &self.address, destination, topic);
                    q.id = next_msg_id();
                    q.correlation_id = id;
                    let _ = self.fabric.send(q);
                }
            }
        }
    }

    /// Fire-and-forget event (metric streaming, heartbeats).
    pub fn fire_event(&self, destination: &str, topic: &str, payload: Vec<u8>) {
        let mut env = Envelope::new(MsgKind::Event, &self.address, destination, topic);
        env.id = next_msg_id();
        env.payload = payload;
        let _ = self.fabric.send(env);
    }

    // ---------------- service loop ----------------

    fn service_loop(self: Arc<Self>, mailbox: Mailbox) {
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            let Some(env) = mailbox.recv_timeout(Duration::from_millis(50)) else {
                continue;
            };
            match env.kind {
                MsgKind::Request => self.on_request(env),
                MsgKind::Query => self.on_query(env),
                MsgKind::Ack => self.on_ack(env),
                MsgKind::Reply => self.on_reply(env),
                MsgKind::Event => {
                    if let Some(h) = self.event_handler.read().unwrap().clone() {
                        h(&env);
                    }
                }
            }
        }
    }

    fn on_request(self: &Arc<Self>, env: Envelope) {
        // Always ack receipt first (cheap; lost acks are covered by the
        // requester's retry + our dedup).
        let mut ack = Envelope::new(MsgKind::Ack, &self.address, &env.source, &env.topic);
        ack.id = next_msg_id();
        ack.correlation_id = env.id;
        let _ = self.fabric.send(ack);

        let key = (env.source.clone(), env.id);
        // Duplicate of a finished request? Re-send the cached reply.
        if let Some(rep) = self.results.lock().unwrap().get(&key) {
            telemetry::bump("reliable.dup_replayed", 1);
            let _ = self.fabric.send(rep.clone());
            return;
        }
        // Duplicate of an in-flight request? The ack is enough.
        {
            let mut inflight = self.inflight.lock().unwrap();
            if !inflight.insert(key.clone()) {
                telemetry::bump("reliable.dup_inflight", 1);
                return;
            }
        }
        let Some(handler) = self.handler.read().unwrap().clone() else {
            self.inflight.lock().unwrap().remove(&key);
            log::warn!("{}: request on {} but no handler", self.address, env.topic);
            return;
        };
        // Process on a worker thread: handlers may run for a whole
        // training round; the service loop must keep acking/answering.
        let me = self.clone();
        std::thread::Builder::new()
            .name(format!("handler-{}", self.address))
            .spawn(move || {
                let mut env = env;
                let reply = match handler(&mut env) {
                    Ok(payload) => {
                        let mut r = env.reply_to(payload);
                        r.id = next_msg_id();
                        r
                    }
                    Err(e) => {
                        let mut r = env.reply_to(Vec::new());
                        r.id = next_msg_id();
                        r.headers.push(("error".into(), e.to_string()));
                        r
                    }
                };
                me.results.lock().unwrap().insert(key.clone(), reply.clone());
                me.inflight.lock().unwrap().remove(&key);
                let _ = me.fabric.send(reply);
            })
            .expect("spawn handler");
    }

    fn on_query(&self, env: Envelope) {
        let key = (env.source.clone(), env.correlation_id);
        if let Some(rep) = self.results.lock().unwrap().get(&key) {
            telemetry::bump("reliable.query_hits", 1);
            let _ = self.fabric.send(rep.clone());
        } else {
            // Not ready: ack the query so the requester knows we're alive.
            let mut ack = Envelope::new(MsgKind::Ack, &self.address, &env.source, &env.topic);
            ack.id = next_msg_id();
            ack.correlation_id = env.correlation_id;
            let _ = self.fabric.send(ack);
        }
    }

    fn on_ack(&self, env: Envelope) {
        if let Some(tx) = self.waiters.lock().unwrap().get(&env.correlation_id) {
            let _ = tx.send(WaiterMsg::Acked);
        }
    }

    fn on_reply(&self, env: Envelope) {
        if let Some(tx) = self.waiters.lock().unwrap().get(&env.correlation_id) {
            let _ = tx.send(WaiterMsg::Reply(env));
        } else {
            telemetry::bump("reliable.orphan_reply", 1);
        }
    }
}

/// Remove the waiter entry when `request` returns (any path).
struct WaiterGuard<'a> {
    m: &'a Messenger,
    id: u64,
}

impl Drop for WaiterGuard<'_> {
    fn drop(&mut self) {
        self.m.waiters.lock().unwrap().remove(&self.id);
    }
}

fn finish(rep: Envelope) -> Result<Envelope, ReliableError> {
    if let Some(err) = rep.header("error") {
        return Err(ReliableError::Remote(err.to_string()));
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flare::fabric::{CcpFabric, ScpFabric};
    use crate::proto::address;
    use crate::transport::fault::{FaultConfig, FaultEndpoint};
    use crate::transport::inproc;

    /// One SCP + one site, optionally lossy in both directions.
    fn federation(drop_prob: f64, seed: u64) -> (Arc<ScpFabric>, Arc<CcpFabric>) {
        let scp = Arc::new(ScpFabric::new());
        let (server_end, client_end) = inproc::pair(address::SERVER, "site-1");
        let server_end: Arc<dyn crate::transport::Endpoint> = if drop_prob > 0.0 {
            Arc::new(FaultEndpoint::new(
                server_end,
                FaultConfig {
                    drop_prob,
                    seed,
                    ..Default::default()
                },
            ))
        } else {
            Arc::new(server_end)
        };
        let client_end: Arc<dyn crate::transport::Endpoint> = if drop_prob > 0.0 {
            Arc::new(FaultEndpoint::new(
                client_end,
                FaultConfig {
                    drop_prob,
                    seed: seed + 1,
                    ..Default::default()
                },
            ))
        } else {
            Arc::new(client_end)
        };
        scp.add_site_link("site-1", server_end);
        let ccp = CcpFabric::new("site-1", client_end);
        (scp, ccp)
    }

    fn echo_handler() -> Handler {
        Arc::new(|env: &mut Envelope| {
            let mut out = env.payload.clone();
            out.reverse();
            Ok(out)
        })
    }

    #[test]
    fn request_reply_clean_network() {
        let (scp, ccp) = federation(0.0, 0);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        server.set_handler(echo_handler());
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        let rep = client
            .request("server:j1", "test", vec![1, 2, 3], RetryPolicy::fast())
            .unwrap();
        assert_eq!(rep.payload, vec![3, 2, 1]);
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn survives_heavy_loss() {
        // 40% loss each way; retries + queries must still complete.
        let (scp, ccp) = federation(0.4, 42);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        server.set_handler(echo_handler());
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        for i in 0..10u8 {
            let rep = client
                .request("server:j1", "test", vec![i], RetryPolicy::fast())
                .unwrap();
            assert_eq!(rep.payload, vec![i]);
        }
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn deadline_aborts_when_peer_missing() {
        let (scp, ccp) = federation(0.0, 0);
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        let policy = RetryPolicy {
            per_try: Duration::from_millis(10),
            query_interval: Duration::from_millis(10),
            deadline: Duration::from_millis(100),
        };
        let err = client
            .request("server:ghost", "test", vec![], policy)
            .unwrap_err();
        assert!(matches!(err, ReliableError::Deadline { .. }), "{err}");
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn handler_executes_once_despite_retries() {
        // Slow handler + tiny per_try forces duplicate request sends;
        // the dedup table must ensure exactly one execution.
        let (scp, ccp) = federation(0.0, 0);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        let count = Arc::new(std::sync::atomic::AtomicU32::new(0));
        let c2 = count.clone();
        server.set_handler(Arc::new(move |env| {
            c2.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(80));
            Ok(env.payload.clone())
        }));
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        let policy = RetryPolicy {
            per_try: Duration::from_millis(5),
            query_interval: Duration::from_millis(5),
            deadline: Duration::from_secs(5),
        };
        let rep = client.request("server:j1", "t", vec![7], policy).unwrap();
        assert_eq!(rep.payload, vec![7]);
        assert_eq!(count.load(Ordering::SeqCst), 1);
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn result_retrieved_via_query_path() {
        // Drop every push Reply by dropping 60% server->client; query
        // path must eventually deliver. (Drops affect acks too, which is
        // fine — retries cover it.)
        let (scp, ccp) = federation(0.6, 7);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        server.set_handler(echo_handler());
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        let rep = client
            .request("server:j1", "t", vec![9, 8], RetryPolicy::fast())
            .unwrap();
        assert_eq!(rep.payload, vec![8, 9]);
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn remote_handler_error_propagates() {
        let (scp, ccp) = federation(0.0, 0);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        server.set_handler(Arc::new(|_| anyhow::bail!("boom")));
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        let err = client
            .request("server:j1", "t", vec![], RetryPolicy::fast())
            .unwrap_err();
        assert!(matches!(err, ReliableError::Remote(ref m) if m == "boom"), "{err}");
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn events_reach_event_handler() {
        let (scp, ccp) = federation(0.0, 0);
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        let (tx, rx) = channel();
        server.set_event_handler(Arc::new(move |env| {
            let _ = tx.send(env.payload.clone());
        }));
        let client = Messenger::spawn(ccp.clone(), "site-1:j1").unwrap();
        client.fire_event("server:j1", "metrics", vec![5, 5]);
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), vec![5, 5]);
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn concurrent_requests_from_many_clients() {
        let scp = Arc::new(ScpFabric::new());
        let server = Messenger::spawn(scp.clone(), "server:j1").unwrap();
        server.set_handler(echo_handler());
        let mut handles = Vec::new();
        let mut ccps = Vec::new();
        for i in 0..4 {
            let site = format!("site-{i}");
            let (server_end, client_end) = inproc::pair(address::SERVER, &site);
            scp.add_site_link(&site, Arc::new(server_end));
            let ccp = CcpFabric::new(&site, Arc::new(client_end));
            ccps.push(ccp.clone());
            let cell = format!("{site}:j1");
            handles.push(std::thread::spawn(move || {
                let client = Messenger::spawn(ccp, &cell).unwrap();
                for k in 0..5u8 {
                    let rep = client
                        .request("server:j1", "t", vec![i as u8, k], RetryPolicy::fast())
                        .unwrap();
                    assert_eq!(rep.payload, vec![k, i as u8]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        scp.shutdown();
        for c in ccps {
            c.shutdown();
        }
    }
}
