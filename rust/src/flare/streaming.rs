//! Chunked large-message streaming over reliable messaging — the paper's
//! §6 future-work direction ("supporting very large messages, up to
//! hundreds of gigabytes", citing [Roth et al., 2024]) scaled to this
//! testbed. A payload is split into chunks, each delivered as its own
//! reliable request (so loss/retry applies per-chunk, not per-blob), with
//! a SHA-256 integrity check on completion.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::flare::reliable::{Messenger, ReliableError, RetryPolicy};
use crate::proto::Envelope;
use crate::util::bytes::{Reader, Writer};

pub const STREAM_TOPIC: &str = "flare.stream";
pub const DEFAULT_CHUNK: usize = 1 << 20; // 1 MiB

#[derive(Debug)]
pub enum StreamError {
    Reliable(ReliableError),
    Checksum,
    Malformed(String),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Reliable(e) => write!(f, "stream: {e}"),
            StreamError::Checksum => write!(f, "stream: checksum mismatch"),
            StreamError::Malformed(what) => write!(f, "stream: malformed chunk: {what}"),
        }
    }
}

impl std::error::Error for StreamError {}

impl From<ReliableError> for StreamError {
    fn from(e: ReliableError) -> Self {
        StreamError::Reliable(e)
    }
}

/// Send `payload` to `destination` in chunks; blocks until the receiver
/// has acknowledged every chunk and verified the checksum.
pub fn send_streamed(
    messenger: &Messenger,
    destination: &str,
    stream_tag: &str,
    payload: &[u8],
    chunk_size: usize,
    policy: RetryPolicy,
) -> Result<(), StreamError> {
    assert!(chunk_size > 0);
    let stream_id = crate::flare::fabric::next_msg_id();
    let total = payload.len();
    let n_chunks = total.div_ceil(chunk_size).max(1);
    let digest = crate::util::hash::sha256(payload);

    for i in 0..n_chunks {
        let start = i * chunk_size;
        let end = (start + chunk_size).min(total);
        let mut w = Writer::with_capacity(64 + end - start);
        w.u64(stream_id);
        w.str(stream_tag);
        w.u32(n_chunks as u32);
        w.u32(i as u32);
        w.u64(total as u64);
        w.bytes(&payload[start..end]);
        if i == n_chunks - 1 {
            w.bytes(&digest);
        } else {
            w.bytes(&[]);
        }
        let rep = messenger.request(destination, STREAM_TOPIC, w.into_bytes(), policy)?;
        if rep.payload == b"checksum-mismatch" {
            return Err(StreamError::Checksum);
        }
    }
    Ok(())
}

struct Partial {
    chunks: Vec<Option<Vec<u8>>>,
    total: usize,
}

/// Receiver-side reassembler. Install [`handler`] output as the
/// messenger's request handler (or delegate to it for STREAM_TOPIC).
/// Completed payloads are handed to `on_complete(stream_tag, bytes)`.
pub struct StreamCollector {
    partials: Mutex<HashMap<u64, Partial>>,
    on_complete: Box<dyn Fn(&str, Vec<u8>) + Send + Sync>,
}

impl StreamCollector {
    pub fn new(on_complete: impl Fn(&str, Vec<u8>) + Send + Sync + 'static) -> Arc<Self> {
        Arc::new(Self {
            partials: Mutex::new(HashMap::new()),
            on_complete: Box::new(on_complete),
        })
    }

    /// Process one stream chunk request; returns the reply payload.
    pub fn handle(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        let mut r = Reader::new(&env.payload);
        let stream_id = r.u64()?;
        let tag = r.str()?.to_string();
        let n_chunks = r.u32()? as usize;
        let idx = r.u32()? as usize;
        let total = r.u64()? as usize;
        let data = r.bytes()?.to_vec();
        let digest = r.bytes()?.to_vec();
        if idx >= n_chunks {
            anyhow::bail!("chunk index {idx} out of range {n_chunks}");
        }

        let mut partials = self.partials.lock().unwrap();
        let p = partials.entry(stream_id).or_insert_with(|| Partial {
            chunks: vec![None; n_chunks],
            total,
        });
        if p.chunks.len() != n_chunks || p.total != total {
            anyhow::bail!("inconsistent stream metadata for {stream_id}");
        }
        p.chunks[idx] = Some(data);

        let complete = p.chunks.iter().all(|c| c.is_some());
        if complete && !digest.is_empty() {
            let p = partials.remove(&stream_id).unwrap();
            let mut payload = Vec::with_capacity(p.total);
            for c in p.chunks {
                payload.extend_from_slice(&c.unwrap());
            }
            let got = crate::util::hash::sha256(&payload);
            if got.as_slice() != digest.as_slice() {
                return Ok(b"checksum-mismatch".to_vec());
            }
            drop(partials);
            (self.on_complete)(&tag, payload);
        }
        Ok(b"ok".to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flare::fabric::{CcpFabric, ScpFabric};
    use crate::proto::address;
    use crate::transport::fault::{FaultConfig, FaultEndpoint};
    use crate::transport::inproc;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn setup(drop_prob: f64) -> (Arc<ScpFabric>, Arc<CcpFabric>) {
        let scp = Arc::new(ScpFabric::new());
        let (se, ce) = inproc::pair(address::SERVER, "site-1");
        let se: Arc<dyn crate::transport::Endpoint> = if drop_prob > 0.0 {
            Arc::new(FaultEndpoint::new(
                se,
                FaultConfig {
                    drop_prob,
                    seed: 11,
                    ..Default::default()
                },
            ))
        } else {
            Arc::new(se)
        };
        scp.add_site_link("site-1", se);
        (scp, CcpFabric::new("site-1", Arc::new(ce)))
    }

    fn run_stream(drop_prob: f64, size: usize, chunk: usize) {
        let (scp, ccp) = setup(drop_prob);
        let server = Messenger::spawn(scp.clone(), "server:j").unwrap();
        let (tx, rx) = channel();
        let collector = StreamCollector::new(move |tag, bytes| {
            tx.send((tag.to_string(), bytes)).unwrap();
        });
        let c2 = collector.clone();
        server.set_handler(Arc::new(move |env| c2.handle(env)));
        let client = Messenger::spawn(ccp.clone(), "site-1:j").unwrap();

        let payload: Vec<u8> = (0..size).map(|i| (i % 251) as u8).collect();
        send_streamed(
            &client,
            "server:j",
            "model-v1",
            &payload,
            chunk,
            RetryPolicy::fast(),
        )
        .unwrap();
        let (tag, got) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(tag, "model-v1");
        assert_eq!(got, payload);
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn single_chunk_stream() {
        run_stream(0.0, 100, 1024);
    }

    #[test]
    fn multi_chunk_stream() {
        run_stream(0.0, 10_000, 512);
    }

    #[test]
    fn exact_multiple_of_chunk() {
        run_stream(0.0, 2048, 512);
    }

    #[test]
    fn empty_payload() {
        run_stream(0.0, 0, 512);
    }

    #[test]
    fn survives_loss() {
        run_stream(0.3, 20_000, 1024);
    }

    #[test]
    fn malformed_chunk_rejected() {
        let collector = StreamCollector::new(|_, _| {});
        let mut w = Writer::new();
        w.u64(1);
        w.str("t");
        w.u32(2); // n_chunks
        w.u32(5); // idx out of range
        w.u64(10);
        w.bytes(&[1]);
        w.bytes(&[]);
        let env = Envelope::new(crate::proto::MsgKind::Request, "a", "b", STREAM_TOPIC)
            .with_payload(w.into_bytes());
        assert!(collector.handle(&env).is_err());
    }
}
