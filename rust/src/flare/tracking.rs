//! Experiment tracking / metric streaming (paper §4 "metric streaming for
//! experiment tracking" and §5.2/Fig. 6): clients call a `SummaryWriter`
//! analogue inside app code; scalars stream to the SCP as fire-and-forget
//! events; the server-side [`MetricStore`] collects per-(job, site, tag)
//! series and exports TSV/JSON (the TensorBoard substitute).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::flare::reliable::Messenger;
use crate::proto::address;
use crate::util::bytes::{Reader, Writer};
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct MetricEvent {
    pub job_id: String,
    pub site: String,
    pub tag: String,
    pub step: u64,
    pub value: f64,
    /// Wall-clock at emission (telemetry only).
    pub wall_ms: u64,
}

impl MetricEvent {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.str(&self.job_id);
        w.str(&self.site);
        w.str(&self.tag);
        w.u64(self.step);
        w.f64(self.value);
        w.u64(self.wall_ms);
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<MetricEvent> {
        let mut r = Reader::new(buf);
        Ok(MetricEvent {
            job_id: r.str()?.to_string(),
            site: r.str()?.to_string(),
            tag: r.str()?.to_string(),
            step: r.u64()?,
            value: r.f64()?,
            wall_ms: r.u64()?,
        })
    }
}

pub const METRICS_TOPIC: &str = "metrics";

/// Client-side writer, FLARE's `from nvflare.client.tracking import
/// SummaryWriter` analogue (paper Listing 3). Cloneable; cheap.
#[derive(Clone)]
pub struct SummaryWriter {
    messenger: Option<Arc<Messenger>>,
    job_id: String,
    site: String,
}

impl SummaryWriter {
    pub fn new(messenger: Arc<Messenger>, job_id: &str, site: &str) -> Self {
        Self {
            messenger: Some(messenger),
            job_id: job_id.to_string(),
            site: site.to_string(),
        }
    }

    /// A writer that discards everything (apps that don't track).
    pub fn disabled() -> Self {
        Self {
            messenger: None,
            job_id: String::new(),
            site: String::new(),
        }
    }

    /// Stream one scalar to the FLARE server (fire-and-forget, like the
    /// paper's `writer.add_scalar("train_loss", v, step)`).
    pub fn add_scalar(&self, tag: &str, value: f64, step: u64) {
        if let Some(m) = &self.messenger {
            let ev = MetricEvent {
                job_id: self.job_id.clone(),
                site: self.site.clone(),
                tag: tag.to_string(),
                step,
                value,
                wall_ms: crate::util::unix_millis(),
            };
            m.fire_event(address::SERVER, METRICS_TOPIC, ev.encode());
        }
    }
}

type SeriesKey = (String, String, String); // (job, site, tag)

/// Server-side collector.
#[derive(Default)]
pub struct MetricStore {
    series: Mutex<BTreeMap<SeriesKey, Vec<(u64, f64, u64)>>>,
}

impl MetricStore {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn record(&self, ev: MetricEvent) {
        self.series
            .lock()
            .unwrap()
            .entry((ev.job_id, ev.site, ev.tag))
            .or_default()
            .push((ev.step, ev.value, ev.wall_ms));
    }

    /// (step, value) points of one series, sorted by step.
    pub fn series(&self, job: &str, site: &str, tag: &str) -> Vec<(u64, f64)> {
        let key = (job.to_string(), site.to_string(), tag.to_string());
        let mut pts: Vec<(u64, f64)> = self
            .series
            .lock()
            .unwrap()
            .get(&key)
            .map(|v| v.iter().map(|(s, val, _)| (*s, *val)).collect())
            .unwrap_or_default();
        pts.sort_by_key(|(s, _)| *s);
        pts
    }

    /// All (site, tag) pairs seen for a job.
    pub fn keys(&self, job: &str) -> Vec<(String, String)> {
        self.series
            .lock()
            .unwrap()
            .keys()
            .filter(|(j, _, _)| j == job)
            .map(|(_, s, t)| (s.clone(), t.clone()))
            .collect()
    }

    /// TSV export: job \t site \t tag \t step \t value \t wall_ms.
    pub fn export_tsv(&self, job: &str) -> String {
        let mut out = String::from("job\tsite\ttag\tstep\tvalue\twall_ms\n");
        for ((j, s, t), pts) in self.series.lock().unwrap().iter() {
            if j != job {
                continue;
            }
            for (step, value, wall) in pts {
                out.push_str(&format!("{j}\t{s}\t{t}\t{step}\t{value}\t{wall}\n"));
            }
        }
        out
    }

    /// JSON export (per-series arrays), for downstream plotting.
    pub fn export_json(&self, job: &str) -> Json {
        let mut obj = BTreeMap::new();
        for ((j, s, t), pts) in self.series.lock().unwrap().iter() {
            if j != job {
                continue;
            }
            let arr = pts
                .iter()
                .map(|(step, v, _)| {
                    Json::Arr(vec![Json::num(*step as f64), Json::num(*v)])
                })
                .collect();
            obj.insert(format!("{s}/{t}"), Json::Arr(arr));
        }
        Json::Obj(obj)
    }
}

/// ASCII sparkline/curve rendering for examples & EXPERIMENTS.md (the
/// TensorBoard-screenshot substitute for Fig. 6).
pub fn render_ascii(title: &str, series: &[(u64, f64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, v) in series {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let n = series.len();
    for (i, &(_, v)) in series.iter().enumerate() {
        let x = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
        let y = ((v - lo) / (hi - lo) * (height - 1) as f64).round() as usize;
        let row = height - 1 - y.min(height - 1);
        grid[row][x] = b'*';
    }
    let mut out = format!("{title}  [min {lo:.4}, max {hi:.4}]\n");
    for row in grid {
        out.push_str("  |");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("  +{}\n", "-".repeat(width)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_roundtrip() {
        let ev = MetricEvent {
            job_id: "j1".into(),
            site: "site-1".into(),
            tag: "train_loss".into(),
            step: 17,
            value: 0.125,
            wall_ms: 99,
        };
        assert_eq!(MetricEvent::decode(&ev.encode()).unwrap(), ev);
    }

    #[test]
    fn store_collects_and_sorts() {
        let store = MetricStore::new();
        for (step, v) in [(2u64, 0.2), (0, 0.4), (1, 0.3)] {
            store.record(MetricEvent {
                job_id: "j".into(),
                site: "s1".into(),
                tag: "loss".into(),
                step,
                value: v,
                wall_ms: 0,
            });
        }
        let pts = store.series("j", "s1", "loss");
        assert_eq!(pts, vec![(0, 0.4), (1, 0.3), (2, 0.2)]);
    }

    #[test]
    fn store_separates_sites_and_jobs() {
        let store = MetricStore::new();
        for site in ["s1", "s2"] {
            store.record(MetricEvent {
                job_id: "j".into(),
                site: site.into(),
                tag: "acc".into(),
                step: 0,
                value: 1.0,
                wall_ms: 0,
            });
        }
        store.record(MetricEvent {
            job_id: "other".into(),
            site: "s1".into(),
            tag: "acc".into(),
            step: 0,
            value: 9.0,
            wall_ms: 0,
        });
        assert_eq!(store.keys("j").len(), 2);
        assert_eq!(store.series("j", "s1", "acc"), vec![(0, 1.0)]);
        assert!(store.series("j", "s3", "acc").is_empty());
    }

    #[test]
    fn tsv_export_shape() {
        let store = MetricStore::new();
        store.record(MetricEvent {
            job_id: "j".into(),
            site: "s1".into(),
            tag: "loss".into(),
            step: 3,
            value: 0.5,
            wall_ms: 1,
        });
        let tsv = store.export_tsv("j");
        let lines: Vec<&str> = tsv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[1].starts_with("j\ts1\tloss\t3\t0.5"));
    }

    #[test]
    fn json_export_keys() {
        let store = MetricStore::new();
        store.record(MetricEvent {
            job_id: "j".into(),
            site: "s1".into(),
            tag: "acc".into(),
            step: 0,
            value: 0.1,
            wall_ms: 0,
        });
        let j = store.export_json("j");
        assert!(!j.get("s1/acc").is_null());
    }

    #[test]
    fn ascii_render_contains_points() {
        let series: Vec<(u64, f64)> = (0..10).map(|i| (i, i as f64)).collect();
        let art = render_ascii("t", &series, 20, 5);
        assert!(art.contains('*'));
        assert!(art.lines().count() >= 6);
        // Handles empty + constant series without panicking.
        assert!(render_ascii("e", &[], 10, 3).contains("no data"));
        let _ = render_ascii("c", &[(0, 1.0), (1, 1.0)], 10, 3);
    }
}
