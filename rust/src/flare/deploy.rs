//! Provisioned (TCP) deployment wiring — the paper's Option 2
//! (`nvflare job submit` against a real federation): the SCP listens on
//! one TCP port; every site dials in with its startup kit. Multiple jobs
//! share that single connection ("without requiring multiple ports to be
//! open on the server host", §2).
//!
//! Connection handshake: the first frame a site sends is `HELLO <site>`;
//! the SCP then installs the link and all further frames are envelopes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::flare::fabric::{CcpFabric, ScpFabric};
use crate::transport::tcp::{connect_retry, TcpTransportListener};
use crate::transport::Endpoint;

const HELLO_PREFIX: &[u8] = b"FLARELINK-HELLO:";

/// Accept-loop handle for the SCP's TCP listener.
pub struct TcpServer {
    stop: Arc<AtomicBool>,
    pub addr: String,
}

impl TcpServer {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }
}

/// Start accepting site connections for `fabric` on `addr`
/// (e.g. "127.0.0.1:0"). Returns the bound address.
pub fn serve_scp_tcp(fabric: Arc<ScpFabric>, addr: &str) -> anyhow::Result<TcpServer> {
    let listener = TcpTransportListener::bind(addr)?;
    let bound = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    std::thread::Builder::new()
        .name("scp-tcp-accept".into())
        .spawn(move || loop {
            if stop2.load(Ordering::Acquire) {
                return;
            }
            // accept blocks; a stopped server exits on next connection
            // or when the process ends (acceptable for a CLI daemon).
            let Ok(ep) = listener.accept() else { return };
            // Handshake: first frame names the site.
            match ep.recv_timeout(Duration::from_secs(10)) {
                Ok(frame) if frame.starts_with(HELLO_PREFIX) => {
                    let site = String::from_utf8_lossy(&frame[HELLO_PREFIX.len()..]).to_string();
                    log::info!("tcp: site '{site}' connected");
                    fabric.add_site_link(&site, Arc::new(ep));
                }
                other => {
                    log::warn!("tcp: connection without HELLO ({other:?}); dropping");
                    ep.close();
                }
            }
        })?;
    Ok(TcpServer { stop, addr: bound })
}

/// Dial the SCP from a site and build its client fabric.
pub fn connect_ccp_tcp(
    site: &str,
    server_addr: &str,
    deadline: Duration,
) -> anyhow::Result<Arc<CcpFabric>> {
    let ep = connect_retry(server_addr, deadline)?;
    let mut hello = HELLO_PREFIX.to_vec();
    hello.extend_from_slice(site.as_bytes());
    ep.send(hello)?;
    Ok(CcpFabric::new(site, Arc::new(ep)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flare::auth::Authorizer;
    use crate::flare::ccp::{Ccp, CcpConfig};
    use crate::flare::job::{JobCtx, JobSpec};
    use crate::flare::provision::{Provisioner, Role};
    use crate::flare::reliable::RetryPolicy;
    use crate::flare::scp::{Scp, ScpConfig};
    use crate::flare::{AppFactory, JobStatus};

    struct EchoApp;

    impl AppFactory for EchoApp {
        fn supports(&self, _: &str) -> bool {
            true
        }
        fn run_client(&self, ctx: JobCtx) -> anyhow::Result<()> {
            ctx.messenger
                .set_handler(Arc::new(|env| Ok(env.payload.clone())));
            while !ctx.aborted() {
                std::thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        }
        fn run_server(&self, ctx: JobCtx) -> anyhow::Result<()> {
            for site in &ctx.participants {
                let cell = crate::proto::address::job_cell(site, &ctx.job_id);
                let rep = ctx
                    .messenger
                    .request(&cell, "echo", vec![9, 9], RetryPolicy::fast())?;
                anyhow::ensure!(rep.payload == vec![9, 9]);
            }
            Ok(())
        }
    }

    /// Full federation over real TCP sockets: provision, register, run a
    /// job, finish.
    #[test]
    fn tcp_federation_end_to_end() {
        let provisioner = Provisioner::new("tcp-proj", b"s3cret");
        let authorizer = Arc::new(Authorizer::new(Provisioner::new("tcp-proj", b"s3cret")));
        let fabric = Arc::new(ScpFabric::new());
        let mut scp_cfg = ScpConfig::default();
        scp_cfg.policy = RetryPolicy::fast();
        let scp = Scp::start(fabric.clone(), authorizer, Arc::new(EchoApp), None, scp_cfg)
            .unwrap();
        let server = serve_scp_tcp(fabric, "127.0.0.1:0").unwrap();

        let mut ccps = Vec::new();
        for site in ["site-1", "site-2"] {
            let kit = provisioner.provision(site, Role::Site, &server.addr);
            let ccp_fabric =
                connect_ccp_tcp(site, &server.addr, Duration::from_secs(5)).unwrap();
            let mut cfg = CcpConfig::default();
            cfg.policy = RetryPolicy::fast();
            ccps.push(Ccp::start(ccp_fabric, &kit, Arc::new(EchoApp), None, cfg).unwrap());
        }
        assert_eq!(scp.registered_sites(), vec!["site-1", "site-2"]);

        scp.submit(JobSpec::new("tcp-job", "echo")).unwrap();
        let status = scp.wait("tcp-job", Duration::from_secs(30)).unwrap();
        assert_eq!(status, JobStatus::Finished, "err={:?}", scp.job_error("tcp-job"));

        for c in ccps {
            c.shutdown();
        }
        server.stop();
        scp.shutdown();
    }

    #[test]
    fn bad_hello_is_dropped() {
        let fabric = Arc::new(ScpFabric::new());
        let server = serve_scp_tcp(fabric.clone(), "127.0.0.1:0").unwrap();
        let ep = crate::transport::tcp::connect(&server.addr).unwrap();
        ep.send(b"GARBAGE".to_vec()).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert!(fabric.connected_sites().is_empty());
        server.stop();
    }

    #[test]
    fn registration_with_wrong_token_rejected_over_tcp() {
        let authorizer = Arc::new(Authorizer::new(Provisioner::new("p", b"real")));
        let fabric = Arc::new(ScpFabric::new());
        let mut scp_cfg = ScpConfig::default();
        scp_cfg.policy = RetryPolicy::fast();
        let scp =
            Scp::start(fabric.clone(), authorizer, Arc::new(EchoApp), None, scp_cfg).unwrap();
        let server = serve_scp_tcp(fabric, "127.0.0.1:0").unwrap();

        // Kit minted by the WRONG provisioner.
        let forged = Provisioner::new("p", b"fake").provision("site-1", Role::Site, "");
        let ccp_fabric = connect_ccp_tcp("site-1", &server.addr, Duration::from_secs(5)).unwrap();
        let mut cfg = CcpConfig::default();
        cfg.policy = RetryPolicy {
            deadline: Duration::from_secs(2),
            ..RetryPolicy::fast()
        };
        let result = Ccp::start(ccp_fabric, &forged, Arc::new(EchoApp), None, cfg);
        assert!(result.is_err(), "forged kit must be rejected");
        assert!(scp.registered_sites().is_empty());
        server.stop();
        scp.shutdown();
    }
}
