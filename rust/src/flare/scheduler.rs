//! Multi-job scheduler (paper §3.1): several FL experiments share one
//! federation; the SCP deploys a queued job when every participating site
//! has free resource slots. FIFO with backfill — a blocked job does not
//! stall smaller jobs behind it (FLARE's resource-based scheduling).

use std::collections::{HashMap, VecDeque};

use crate::flare::job::JobSpec;

#[derive(Debug)]
pub struct Scheduler {
    /// Total slots per site.
    capacity: HashMap<String, u32>,
    /// Slots currently in use per site.
    in_use: HashMap<String, u32>,
    /// FIFO of queued jobs.
    queue: VecDeque<JobSpec>,
    /// Cap on simultaneously running jobs (0 = unlimited).
    max_concurrent: usize,
    running: usize,
}

impl Scheduler {
    pub fn new(max_concurrent: usize) -> Self {
        Self {
            capacity: HashMap::new(),
            in_use: HashMap::new(),
            queue: VecDeque::new(),
            max_concurrent,
            running: 0,
        }
    }

    /// Register/refresh a site's slot capacity.
    pub fn set_site_capacity(&mut self, site: &str, slots: u32) {
        self.capacity.insert(site.to_string(), slots);
        self.in_use.entry(site.to_string()).or_insert(0);
    }

    pub fn remove_site(&mut self, site: &str) {
        self.capacity.remove(site);
        self.in_use.remove(site);
    }

    pub fn enqueue(&mut self, spec: JobSpec) {
        self.queue.push_back(spec);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running
    }

    pub fn free_slots(&self, site: &str) -> u32 {
        let cap = self.capacity.get(site).copied().unwrap_or(0);
        let used = self.in_use.get(site).copied().unwrap_or(0);
        cap.saturating_sub(used)
    }

    /// Effective participant list: explicit sites, or all known sites.
    pub fn participants(&self, spec: &JobSpec) -> Vec<String> {
        let mut sites = if spec.sites.is_empty() {
            self.capacity.keys().cloned().collect::<Vec<_>>()
        } else {
            spec.sites.clone()
        };
        sites.sort();
        sites
    }

    fn fits(&self, spec: &JobSpec) -> bool {
        let sites = self.participants(spec);
        if sites.is_empty() {
            return false; // nothing to run on yet
        }
        sites.iter().all(|s| {
            self.capacity.contains_key(s) && self.free_slots(s) >= spec.resources_per_site
        })
    }

    /// Pop every queued job that can start now (first-fit backfill),
    /// reserving its slots. Caller deploys the returned specs.
    pub fn schedule(&mut self) -> Vec<JobSpec> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.max_concurrent > 0 && self.running + out.len() >= self.max_concurrent {
                break;
            }
            if self.fits(&self.queue[i]) {
                let spec = self.queue.remove(i).unwrap();
                for site in self.participants(&spec) {
                    *self.in_use.get_mut(&site).unwrap() += spec.resources_per_site;
                }
                out.push(spec);
            } else {
                i += 1;
            }
        }
        self.running += out.len();
        out
    }

    /// Release a finished/aborted job's slots.
    pub fn release(&mut self, spec: &JobSpec) {
        for site in self.participants(spec) {
            if let Some(used) = self.in_use.get_mut(&site) {
                *used = used.saturating_sub(spec.resources_per_site);
            }
        }
        self.running = self.running.saturating_sub(1);
    }

    /// Drop a queued job by id; true if found.
    pub fn dequeue(&mut self, job_id: &str) -> bool {
        if let Some(pos) = self.queue.iter().position(|s| s.id == job_id) {
            self.queue.remove(pos);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{gen_u64, gen_vec, prop_check, Gen};
    use crate::util::rng::Rng;

    fn sched(sites: &[(&str, u32)]) -> Scheduler {
        let mut s = Scheduler::new(0);
        for (name, cap) in sites {
            s.set_site_capacity(name, *cap);
        }
        s
    }

    fn job(id: &str, sites: &[&str], res: u32) -> JobSpec {
        let mut j = JobSpec::new(id, "echo").with_sites(sites);
        j.resources_per_site = res;
        j
    }

    #[test]
    fn schedules_when_capacity_available() {
        let mut s = sched(&[("a", 1), ("b", 1)]);
        s.enqueue(job("j1", &[], 1));
        let out = s.schedule();
        assert_eq!(out.len(), 1);
        assert_eq!(s.free_slots("a"), 0);
        assert_eq!(s.free_slots("b"), 0);
    }

    #[test]
    fn second_job_waits_then_runs_after_release() {
        let mut s = sched(&[("a", 1)]);
        s.enqueue(job("j1", &["a"], 1));
        s.enqueue(job("j2", &["a"], 1));
        let first = s.schedule();
        assert_eq!(first.len(), 1);
        assert!(s.schedule().is_empty());
        s.release(&first[0]);
        let second = s.schedule();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].id, "j2");
    }

    #[test]
    fn concurrent_jobs_share_multi_slot_sites() {
        // The paper's Fig. 2: J1, J2, J3 run simultaneously on shared sites.
        let mut s = sched(&[("a", 3), ("b", 3)]);
        for i in 0..3 {
            s.enqueue(job(&format!("j{i}"), &[], 1));
        }
        assert_eq!(s.schedule().len(), 3);
        assert_eq!(s.running(), 3);
    }

    #[test]
    fn backfill_skips_blocked_head() {
        let mut s = sched(&[("a", 2), ("b", 1)]);
        s.enqueue(job("big", &["a", "b"], 1));
        assert_eq!(s.schedule().len(), 1); // big takes b's only slot
        s.enqueue(job("blocked", &["b"], 1)); // needs b: blocked
        s.enqueue(job("small", &["a"], 1)); // fits on a
        let out = s.schedule();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, "small");
    }

    #[test]
    fn max_concurrent_respected() {
        let mut s = Scheduler::new(2);
        s.set_site_capacity("a", 10);
        for i in 0..5 {
            s.enqueue(job(&format!("j{i}"), &["a"], 1));
        }
        assert_eq!(s.schedule().len(), 2);
        assert!(s.schedule().is_empty());
    }

    #[test]
    fn unknown_site_blocks_job() {
        let mut s = sched(&[("a", 1)]);
        s.enqueue(job("j", &["ghost"], 1));
        assert!(s.schedule().is_empty());
    }

    #[test]
    fn dequeue_removes_queued() {
        let mut s = sched(&[("a", 0)]);
        s.enqueue(job("j", &["a"], 1));
        assert!(s.dequeue("j"));
        assert!(!s.dequeue("j"));
        assert_eq!(s.queued(), 0);
    }

    // ------------------------------------------------------------------
    // Property tests: scheduler invariants under random workloads
    // ------------------------------------------------------------------

    /// Random (n_sites, per-site capacity, jobs as (n_sites_used, res)).
    struct WorkloadGen;

    #[derive(Clone, Debug)]
    struct Workload {
        caps: Vec<u32>,
        jobs: Vec<(usize, u32)>, // (how many sites it uses, resources)
    }

    impl Gen for WorkloadGen {
        type Value = Workload;
        fn generate(&self, rng: &mut Rng) -> Workload {
            let n_sites = rng.range_u64(1, 4) as usize;
            let caps = (0..n_sites).map(|_| rng.range_u64(1, 4) as u32).collect();
            let n_jobs = rng.range_u64(1, 12) as usize;
            let jobs = (0..n_jobs)
                .map(|_| {
                    (
                        rng.range_u64(1, n_sites as u64) as usize,
                        rng.range_u64(1, 3) as u32,
                    )
                })
                .collect();
            Workload { caps, jobs }
        }
        fn shrink(&self, v: &Workload) -> Vec<Workload> {
            let mut out = Vec::new();
            if v.jobs.len() > 1 {
                let mut c = v.clone();
                c.jobs.pop();
                out.push(c);
            }
            out
        }
    }

    #[test]
    fn prop_capacity_never_exceeded_and_all_jobs_complete() {
        prop_check("scheduler invariants", 200, WorkloadGen, |w| {
            let mut s = Scheduler::new(0);
            let site_names: Vec<String> =
                (0..w.caps.len()).map(|i| format!("s{i}")).collect();
            for (i, cap) in w.caps.iter().enumerate() {
                s.set_site_capacity(&site_names[i], *cap);
            }
            let mut specs = Vec::new();
            for (i, (k, res)) in w.jobs.iter().enumerate() {
                let sites: Vec<&str> = site_names[..*k].iter().map(|s| s.as_str()).collect();
                let mut j = JobSpec::new(&format!("j{i}"), "echo").with_sites(&sites);
                // Clamp resources to what the smallest used site can ever
                // hold, else the job legitimately never runs.
                j.resources_per_site =
                    (*res).min(*w.caps[..*k].iter().min().unwrap());
                specs.push(j);
            }
            for spec in specs {
                s.enqueue(spec);
            }
            let mut completed = 0;
            let total = w.jobs.len();
            let mut running: Vec<JobSpec> = Vec::new();
            // Drive to quiescence; finish one running job per step.
            for _ in 0..total * 4 + 4 {
                let newly = s.schedule();
                // Invariant: in_use <= capacity at all times.
                for name in &site_names {
                    if s.free_slots(name) > *s.capacity.get(name).unwrap() {
                        return false;
                    }
                }
                running.extend(newly);
                if let Some(done) = running.pop() {
                    s.release(&done);
                    completed += 1;
                }
            }
            completed == total && s.queued() == 0
        });
    }

    #[test]
    fn prop_release_restores_capacity() {
        prop_check(
            "release restores",
            100,
            gen_vec(gen_u64(1, 3), 1, 6),
            |resources| {
                let mut s = Scheduler::new(0);
                s.set_site_capacity("a", 10);
                let before = s.free_slots("a");
                let mut specs = Vec::new();
                for (i, r) in resources.iter().enumerate() {
                    let mut j = JobSpec::new(&format!("j{i}"), "e").with_sites(&["a"]);
                    j.resources_per_site = *r as u32;
                    specs.push(j);
                }
                for sp in &specs {
                    s.enqueue(sp.clone());
                }
                let started = s.schedule();
                for sp in &started {
                    s.release(sp);
                }
                s.free_slots("a") == before
            },
        );
    }
}
