//! Provisioning: federation spec → per-site *startup kits* (paper §2:
//! "facilitates the provisioning of startup kits, including
//! certificates"). Real FLARE issues X.509 certs; offline we issue
//! HMAC-SHA256 identity tokens over (project, site, role) signed with the
//! project root secret — same trust model (only the provisioner can mint,
//! the server can verify), zero external PKI. The same root secret also
//! derives the per-node *wire keys* that [`crate::flower::authn`] uses to
//! MAC every v2 frame, so transport authentication is rooted in
//! provisioning exactly like FLARE's cert chain.

use crate::util::hash::{hex, unhex, HmacSha256};

/// Domain-separation label for per-node wire keys (distinct from identity
/// tokens so a leaked token never doubles as a signing key).
const NODE_KEY_LABEL: &[u8] = b"flarelink-node-key";

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Admin,
    Site,
    Server,
}

impl Role {
    pub fn as_str(self) -> &'static str {
        match self {
            Role::Admin => "admin",
            Role::Site => "site",
            Role::Server => "server",
        }
    }

    pub fn parse(s: &str) -> Option<Role> {
        match s {
            "admin" => Some(Role::Admin),
            "site" => Some(Role::Site),
            "server" => Some(Role::Server),
            _ => None,
        }
    }
}

/// What a participant receives from the provisioner (FLARE's startup-kit
/// zip: identity + server address + cert).
#[derive(Clone, Debug)]
pub struct StartupKit {
    pub project: String,
    pub name: String,
    pub role: Role,
    /// Hex HMAC token proving (project, name, role) was minted by the
    /// project provisioner.
    pub token: String,
    /// Server endpoint to dial (TCP deployments; empty in simulator).
    pub server_addr: String,
}

/// Project provisioner holding the root secret.
pub struct Provisioner {
    project: String,
    secret: Vec<u8>,
}

impl Provisioner {
    pub fn new(project: &str, secret: &[u8]) -> Self {
        Self {
            project: project.to_string(),
            secret: secret.to_vec(),
        }
    }

    fn sign(&self, name: &str, role: Role) -> String {
        let mut mac = HmacSha256::new(&self.secret);
        mac.update(self.project.as_bytes());
        mac.update(b"\x00");
        mac.update(name.as_bytes());
        mac.update(b"\x00");
        mac.update(role.as_str().as_bytes());
        hex(&mac.finalize())
    }

    /// Mint a startup kit for one participant.
    pub fn provision(&self, name: &str, role: Role, server_addr: &str) -> StartupKit {
        StartupKit {
            project: self.project.clone(),
            name: name.to_string(),
            role,
            token: self.sign(name, role),
            server_addr: server_addr.to_string(),
        }
    }

    /// Verify a presented token (fixed-shape compare, no early exit).
    pub fn verify(&self, name: &str, role: Role, token: &str) -> bool {
        let expected = self.sign(name, role);
        match (unhex(token), unhex(&expected)) {
            (Some(a), Some(b)) => crate::util::hash::macs_equal(&a, &b),
            _ => false,
        }
    }

    /// Derive the wire-authentication key for one node id. Only the
    /// provisioner (and the SuperLink it hands the derivation secret to)
    /// can mint these; each node receives exactly its own key in its
    /// startup kit, so a client can sign as itself but never as a peer.
    pub fn node_key(&self, node_id: u64) -> [u8; 32] {
        derive_node_key(&self.secret, &self.project, node_id)
    }
}

/// Shared node-key derivation: HMAC(secret, label ‖ 0 ‖ project ‖ 0 ‖ id).
pub fn derive_node_key(secret: &[u8], project: &str, node_id: u64) -> [u8; 32] {
    let mut mac = HmacSha256::new(secret);
    mac.update(NODE_KEY_LABEL);
    mac.update(b"\x00");
    mac.update(project.as_bytes());
    mac.update(b"\x00");
    mac.update(&node_id.to_le_bytes());
    mac.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::{hex, unhex};

    #[test]
    fn minted_kit_verifies() {
        let p = Provisioner::new("proj", b"root-secret");
        let kit = p.provision("site-1", Role::Site, "127.0.0.1:9");
        assert!(p.verify("site-1", Role::Site, &kit.token));
    }

    #[test]
    fn wrong_name_role_or_token_rejected() {
        let p = Provisioner::new("proj", b"root-secret");
        let kit = p.provision("site-1", Role::Site, "");
        assert!(!p.verify("site-2", Role::Site, &kit.token));
        assert!(!p.verify("site-1", Role::Admin, &kit.token));
        assert!(!p.verify("site-1", Role::Site, "deadbeef"));
        assert!(!p.verify("site-1", Role::Site, "not-hex!"));
    }

    #[test]
    fn different_project_secret_rejected() {
        let p1 = Provisioner::new("proj", b"secret-a");
        let p2 = Provisioner::new("proj", b"secret-b");
        let kit = p1.provision("site-1", Role::Site, "");
        assert!(!p2.verify("site-1", Role::Site, &kit.token));
    }

    #[test]
    fn tokens_differ_per_site_and_role() {
        let p = Provisioner::new("proj", b"s");
        let a = p.provision("site-1", Role::Site, "").token;
        let b = p.provision("site-2", Role::Site, "").token;
        let c = p.provision("site-1", Role::Admin, "").token;
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn node_keys_differ_per_node_and_secret() {
        let p = Provisioner::new("proj", b"s");
        assert_ne!(p.node_key(1), p.node_key(2));
        assert_eq!(p.node_key(7), derive_node_key(b"s", "proj", 7));
        assert_ne!(
            derive_node_key(b"s", "proj", 1),
            derive_node_key(b"other", "proj", 1)
        );
        // Domain separation: a node key is never a valid identity token.
        let kit = p.provision("site-1", Role::Site, "");
        assert_ne!(kit.token, hex(&p.node_key(1)));
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(unhex(&hex(&[0, 255, 16])).unwrap(), vec![0, 255, 16]);
        assert!(unhex("abc").is_none());
    }
}
