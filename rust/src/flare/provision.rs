//! Provisioning: federation spec → per-site *startup kits* (paper §2:
//! "facilitates the provisioning of startup kits, including
//! certificates"). Real FLARE issues X.509 certs; offline we issue
//! HMAC-SHA256 identity tokens over (project, site, role) signed with the
//! project root secret — same trust model (only the provisioner can mint,
//! the server can verify), zero external PKI.

use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Admin,
    Site,
    Server,
}

impl Role {
    pub fn as_str(self) -> &'static str {
        match self {
            Role::Admin => "admin",
            Role::Site => "site",
            Role::Server => "server",
        }
    }

    pub fn parse(s: &str) -> Option<Role> {
        match s {
            "admin" => Some(Role::Admin),
            "site" => Some(Role::Site),
            "server" => Some(Role::Server),
            _ => None,
        }
    }
}

/// What a participant receives from the provisioner (FLARE's startup-kit
/// zip: identity + server address + cert).
#[derive(Clone, Debug)]
pub struct StartupKit {
    pub project: String,
    pub name: String,
    pub role: Role,
    /// Hex HMAC token proving (project, name, role) was minted by the
    /// project provisioner.
    pub token: String,
    /// Server endpoint to dial (TCP deployments; empty in simulator).
    pub server_addr: String,
}

/// Project provisioner holding the root secret.
pub struct Provisioner {
    project: String,
    secret: Vec<u8>,
}

impl Provisioner {
    pub fn new(project: &str, secret: &[u8]) -> Self {
        Self {
            project: project.to_string(),
            secret: secret.to_vec(),
        }
    }

    fn sign(&self, name: &str, role: Role) -> String {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(self.project.as_bytes());
        mac.update(b"\x00");
        mac.update(name.as_bytes());
        mac.update(b"\x00");
        mac.update(role.as_str().as_bytes());
        hex(&mac.finalize().into_bytes())
    }

    /// Mint a startup kit for one participant.
    pub fn provision(&self, name: &str, role: Role, server_addr: &str) -> StartupKit {
        StartupKit {
            project: self.project.clone(),
            name: name.to_string(),
            role,
            token: self.sign(name, role),
            server_addr: server_addr.to_string(),
        }
    }

    /// Verify a presented token (constant-time via the hmac crate).
    pub fn verify(&self, name: &str, role: Role, token: &str) -> bool {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(self.project.as_bytes());
        mac.update(b"\x00");
        mac.update(name.as_bytes());
        mac.update(b"\x00");
        mac.update(role.as_str().as_bytes());
        match unhex(token) {
            Some(bytes) => mac.verify_slice(&bytes).is_ok(),
            None => false,
        }
    }
}

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{:02x}", b));
    }
    s
}

fn unhex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_kit_verifies() {
        let p = Provisioner::new("proj", b"root-secret");
        let kit = p.provision("site-1", Role::Site, "127.0.0.1:9");
        assert!(p.verify("site-1", Role::Site, &kit.token));
    }

    #[test]
    fn wrong_name_role_or_token_rejected() {
        let p = Provisioner::new("proj", b"root-secret");
        let kit = p.provision("site-1", Role::Site, "");
        assert!(!p.verify("site-2", Role::Site, &kit.token));
        assert!(!p.verify("site-1", Role::Admin, &kit.token));
        assert!(!p.verify("site-1", Role::Site, "deadbeef"));
        assert!(!p.verify("site-1", Role::Site, "not-hex!"));
    }

    #[test]
    fn different_project_secret_rejected() {
        let p1 = Provisioner::new("proj", b"secret-a");
        let p2 = Provisioner::new("proj", b"secret-b");
        let kit = p1.provision("site-1", Role::Site, "");
        assert!(!p2.verify("site-1", Role::Site, &kit.token));
    }

    #[test]
    fn tokens_differ_per_site_and_role() {
        let p = Provisioner::new("proj", b"s");
        let a = p.provision("site-1", Role::Site, "").token;
        let b = p.provision("site-2", Role::Site, "").token;
        let c = p.provision("site-1", Role::Admin, "").token;
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(unhex(&hex(&[0, 255, 16])).unwrap(), vec![0, 255, 16]);
        assert!(unhex("abc").is_none());
    }
}
