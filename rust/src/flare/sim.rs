//! Federation assembly: the `nvflare simulator` analogue (paper §5,
//! deploy Option 1) plus TCP wiring helpers for provisioned deployments
//! (Option 2). Builds an SCP + N CCPs, connected over in-proc endpoints
//! (optionally fault-injected) or TCP, with provisioning and
//! authentication performed exactly as in a real deployment.

use std::sync::Arc;
use std::time::Duration;

use crate::flare::auth::Authorizer;
use crate::flare::ccp::{Ccp, CcpConfig};
use crate::flare::fabric::{CcpFabric, ScpFabric};
use crate::flare::job::AppFactory;
use crate::flare::provision::{Provisioner, Role, StartupKit};
use crate::flare::reliable::RetryPolicy;
use crate::flare::scp::{Scp, ScpConfig};
use crate::proto::address;
use crate::transport::fault::{FaultConfig, FaultEndpoint, FaultHandle};
use crate::transport::inproc;
use crate::transport::Endpoint;

pub struct FederationBuilder {
    project: String,
    secret: Vec<u8>,
    sites: Vec<String>,
    drop_prob: f64,
    latency: Duration,
    fault_seed: u64,
    chaos: bool,
    direct_pairs: Vec<(String, String)>,
    scp_cfg: ScpConfig,
    ccp_cfg: CcpConfig,
    compute: Option<crate::runtime::ComputeHandle>,
}

impl FederationBuilder {
    pub fn new(project: &str) -> Self {
        Self {
            project: project.to_string(),
            secret: b"flarelink-project-secret".to_vec(),
            sites: Vec::new(),
            drop_prob: 0.0,
            latency: Duration::ZERO,
            fault_seed: 0,
            chaos: false,
            direct_pairs: Vec::new(),
            scp_cfg: ScpConfig::default(),
            ccp_cfg: CcpConfig::default(),
            compute: None,
        }
    }

    pub fn sites(mut self, n: usize) -> Self {
        self.sites = (1..=n).map(|i| format!("site-{i}")).collect();
        self
    }

    pub fn named_sites(mut self, names: &[&str]) -> Self {
        self.sites = names.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Inject loss/latency on every SCP<->site link (E3/E5 benches).
    pub fn faults(mut self, drop_prob: f64, latency: Duration, seed: u64) -> Self {
        self.drop_prob = drop_prob;
        self.latency = latency;
        self.fault_seed = seed;
        self
    }

    /// Seed every stochastic piece of the simulated federation (today:
    /// the per-site fault layers, whose per-link streams derive from
    /// this base — and thereby frame-drop choices and delivery order).
    /// Chaos tests log this seed so any failure reproduces from one
    /// number; composes with [`FederationBuilder::faults`] (overrides
    /// its seed) and [`FederationBuilder::chaos`].
    pub fn seed(mut self, seed: u64) -> Self {
        self.fault_seed = seed;
        self
    }

    /// Wrap every SCP<->site link in a (zero-loss) fault endpoint and
    /// expose per-site [`FaultHandle`]s on the built [`Federation`], so
    /// chaos tests can [`Federation::kill_site`] mid-round. Composes
    /// with [`FederationBuilder::faults`].
    pub fn chaos(mut self) -> Self {
        self.chaos = true;
        self
    }

    /// Permit a direct P2P link between two sites (paper §3.1: "direct
    /// connections could be established ... if network policy permits").
    pub fn allow_direct(mut self, a: &str, b: &str) -> Self {
        self.direct_pairs.push((a.to_string(), b.to_string()));
        self
    }

    pub fn scp_config(mut self, cfg: ScpConfig) -> Self {
        self.scp_cfg = cfg;
        self
    }

    pub fn ccp_config(mut self, cfg: CcpConfig) -> Self {
        self.ccp_cfg = cfg;
        self
    }

    pub fn retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.scp_cfg.policy = policy;
        self.ccp_cfg.policy = policy;
        self
    }

    pub fn compute(mut self, handle: crate::runtime::ComputeHandle) -> Self {
        self.compute = Some(handle);
        self
    }

    fn wrap(
        &self,
        ep: inproc::InprocEndpoint,
        seed_offset: u64,
        handles: &mut Vec<FaultHandle>,
    ) -> Arc<dyn Endpoint> {
        if self.chaos || self.drop_prob > 0.0 || !self.latency.is_zero() {
            let fault = FaultEndpoint::new(
                ep,
                FaultConfig {
                    drop_prob: self.drop_prob,
                    latency: self.latency,
                    seed: self.fault_seed + seed_offset,
                },
            );
            handles.push(fault.handle());
            Arc::new(fault)
        } else {
            Arc::new(ep)
        }
    }

    /// Build the in-process federation and wait until all sites are
    /// registered.
    pub fn build(self, app_factory: Arc<dyn AppFactory>) -> anyhow::Result<Federation> {
        if self.chaos || self.drop_prob > 0.0 || !self.latency.is_zero() {
            // One number reproduces every fault-layer decision.
            log::info!(
                "federation {}: fault seed {} (drop {}, latency {:?})",
                self.project,
                self.fault_seed,
                self.drop_prob,
                self.latency
            );
        }
        let provisioner = Provisioner::new(&self.project, &self.secret);
        let admin_kit = provisioner.provision("admin", Role::Admin, "");
        let authorizer = Arc::new(Authorizer::new(Provisioner::new(
            &self.project,
            &self.secret,
        )));

        let fabric = Arc::new(ScpFabric::new());
        let scp = Scp::start(
            fabric.clone(),
            authorizer,
            app_factory.clone(),
            self.compute.clone(),
            self.scp_cfg.clone(),
        )?;

        let mut ccps = Vec::new();
        let mut site_faults = Vec::new();
        for (i, site) in self.sites.iter().enumerate() {
            let kit = provisioner.provision(site, Role::Site, "");
            let (server_end, client_end) = inproc::pair(address::SERVER, site);
            let mut handles = Vec::new();
            fabric.add_site_link(site, self.wrap(server_end, i as u64 * 2, &mut handles));
            let ccp_fabric =
                CcpFabric::new(site, self.wrap(client_end, i as u64 * 2 + 1, &mut handles));
            site_faults.push((site.clone(), handles));
            let ccp = Ccp::start(
                ccp_fabric,
                &kit,
                app_factory.clone(),
                self.compute.clone(),
                self.ccp_cfg.clone(),
            )?;
            ccps.push(ccp);
        }

        // Direct P2P links (never fault-wrapped: they model same-DC links).
        for (a, b) in &self.direct_pairs {
            let ia = self.sites.iter().position(|s| s == a);
            let ib = self.sites.iter().position(|s| s == b);
            if let (Some(ia), Some(ib)) = (ia, ib) {
                let (ea, eb) = inproc::pair(a, b);
                ccps[ia].fabric.add_direct(b, Arc::new(ea));
                ccps[ib].fabric.add_direct(a, Arc::new(eb));
            }
        }

        // Registration is synchronous inside Ccp::start, so all sites are
        // known; double-check for clarity.
        let t0 = std::time::Instant::now();
        while scp.registered_sites().len() < self.sites.len() {
            if t0.elapsed() > Duration::from_secs(10) {
                anyhow::bail!("sites failed to register");
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        Ok(Federation {
            scp,
            ccps,
            admin_kit,
            site_faults,
            dumped: std::sync::atomic::AtomicBool::new(false),
        })
    }
}

/// A running federation (simulator mode).
pub struct Federation {
    pub scp: Arc<Scp>,
    pub ccps: Vec<Arc<Ccp>>,
    pub admin_kit: StartupKit,
    /// Per-site fault handles on the SCP<->site links (both directions),
    /// present when the federation was built with
    /// [`FederationBuilder::chaos`] or [`FederationBuilder::faults`].
    pub site_faults: Vec<(String, Vec<FaultHandle>)>,
    /// Teardown counter dump fires once even though `shutdown` runs
    /// both explicitly and from `Drop`.
    dumped: std::sync::atomic::AtomicBool,
}

impl Federation {
    fn each_site_fault(&self, site: &str, f: impl Fn(&FaultHandle)) -> bool {
        let mut hit = false;
        for (name, handles) in &self.site_faults {
            if name == site {
                for h in handles {
                    f(h);
                    hit = true;
                }
            }
        }
        hit
    }

    /// Take every fault-wrapped link of `site` dark (crash/partition the
    /// site). Returns false when the site has no fault layer (build the
    /// federation with [`FederationBuilder::chaos`]).
    pub fn kill_site(&self, site: &str) -> bool {
        self.each_site_fault(site, |h| h.kill())
    }

    /// Restore a killed site's links (frames lost while dark stay lost).
    pub fn heal_site(&self, site: &str) -> bool {
        self.each_site_fault(site, |h| h.heal())
    }

    pub fn shutdown(&self) {
        for ccp in &self.ccps {
            ccp.shutdown();
        }
        self.scp.shutdown();
        // Observability teardown: surface the process-wide counters
        // (WAL appends/bytes, checkpoints, recovery replays, routing
        // stats) once per federation, when INFO logging is on. Sharded
        // runs also print the per-shard `name[shard-k]` breakdown,
        // indented beneath each authoritative unlabelled total.
        if !self
            .dumped
            .swap(true, std::sync::atomic::Ordering::SeqCst)
        {
            crate::telemetry::dump_counters(&format!(
                "federation {} teardown",
                self.admin_kit.project
            ));
        }
    }
}

impl Drop for Federation {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flare::job::{JobCtx, JobSpec};
    use crate::flare::scp::topics;
    use crate::proto::Envelope;
    use crate::util::json::Json;

    /// Test app: server asks each client to double a number; clients
    /// serve until stopped.
    struct DoubleApp;

    impl AppFactory for DoubleApp {
        fn supports(&self, app: &str) -> bool {
            app == "double"
        }

        fn run_client(&self, ctx: JobCtx) -> anyhow::Result<()> {
            ctx.messenger.set_handler(Arc::new(|env: &mut Envelope| {
                let x = env.payload[0];
                Ok(vec![x * 2])
            }));
            while !ctx.aborted() {
                std::thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        }

        fn run_server(&self, ctx: JobCtx) -> anyhow::Result<()> {
            let rounds = ctx.config.get("rounds").as_u64().unwrap_or(1);
            for round in 0..rounds {
                for site in &ctx.participants {
                    let cell = crate::proto::address::job_cell(site, &ctx.job_id);
                    let rep = ctx.messenger.request(
                        &cell,
                        "double",
                        vec![round as u8 + 1],
                        RetryPolicy::fast(),
                    )?;
                    anyhow::ensure!(rep.payload == vec![(round as u8 + 1) * 2]);
                    ctx.tracker
                        .add_scalar("doubled", rep.payload[0] as f64, round);
                }
            }
            Ok(())
        }
    }

    /// App whose server fails immediately.
    struct FailApp;

    impl AppFactory for FailApp {
        fn supports(&self, _: &str) -> bool {
            true
        }
        fn run_client(&self, _: JobCtx) -> anyhow::Result<()> {
            Ok(())
        }
        fn run_server(&self, _: JobCtx) -> anyhow::Result<()> {
            anyhow::bail!("server app exploded")
        }
    }

    /// App that runs forever until aborted.
    struct SpinApp;

    impl AppFactory for SpinApp {
        fn supports(&self, _: &str) -> bool {
            true
        }
        fn run_client(&self, ctx: JobCtx) -> anyhow::Result<()> {
            while !ctx.aborted() {
                std::thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        }
        fn run_server(&self, ctx: JobCtx) -> anyhow::Result<()> {
            while !ctx.aborted() {
                std::thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        }
    }

    fn fast_cfgs(b: FederationBuilder) -> FederationBuilder {
        b.retry_policy(RetryPolicy::fast())
    }

    #[test]
    fn end_to_end_job_lifecycle() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(FederationBuilder::new("t").sites(2))
            .build(Arc::new(DoubleApp))
            .unwrap();
        let spec = JobSpec::new("job-1", "double")
            .with_config(Json::obj(vec![("rounds", Json::num(3))]));
        fed.scp.submit(spec).unwrap();
        let status = fed.scp.wait("job-1", Duration::from_secs(20)).unwrap();
        assert_eq!(status, JobStatus::Finished, "err={:?}", fed.scp.job_error("job-1"));
        // Server-side tracker streamed metrics through the fabric.
        let pts = fed.scp.metrics.series("job-1", "server", "doubled");
        assert_eq!(pts.len(), 3 * 2); // rounds x sites, same step per site pair
        fed.shutdown();
    }

    #[test]
    fn job_survives_lossy_links() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(
            FederationBuilder::new("t")
                .sites(2)
                .faults(0.3, Duration::ZERO, 99),
        )
        .build(Arc::new(DoubleApp))
        .unwrap();
        let spec = JobSpec::new("lossy", "double")
            .with_config(Json::obj(vec![("rounds", Json::num(2))]));
        fed.scp.submit(spec).unwrap();
        let status = fed.scp.wait("lossy", Duration::from_secs(30)).unwrap();
        assert_eq!(status, JobStatus::Finished, "err={:?}", fed.scp.job_error("lossy"));
        fed.shutdown();
    }

    #[test]
    fn failed_server_app_fails_job() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(FederationBuilder::new("t").sites(1))
            .build(Arc::new(FailApp))
            .unwrap();
        fed.scp.submit(JobSpec::new("bad", "x")).unwrap();
        let status = fed.scp.wait("bad", Duration::from_secs(20)).unwrap();
        assert_eq!(status, JobStatus::Failed);
        assert!(fed.scp.job_error("bad").unwrap().contains("exploded"));
        fed.shutdown();
    }

    #[test]
    fn abort_running_job() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(FederationBuilder::new("t").sites(1))
            .build(Arc::new(SpinApp))
            .unwrap();
        fed.scp.submit(JobSpec::new("spin", "x")).unwrap();
        // wait until running
        let t0 = std::time::Instant::now();
        while fed.scp.status("spin") != Some(JobStatus::Running) {
            assert!(t0.elapsed() < Duration::from_secs(10));
            std::thread::sleep(Duration::from_millis(5));
        }
        fed.scp.abort("spin").unwrap();
        let status = fed.scp.wait("spin", Duration::from_secs(10)).unwrap();
        assert_eq!(status, JobStatus::Aborted);
        fed.shutdown();
    }

    #[test]
    fn concurrent_jobs_on_shared_sites() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(FederationBuilder::new("t").sites(2))
            .build(Arc::new(DoubleApp))
            .unwrap();
        for i in 0..3 {
            let spec = JobSpec::new(&format!("j{i}"), "double")
                .with_config(Json::obj(vec![("rounds", Json::num(2))]));
            fed.scp.submit(spec).unwrap();
        }
        for i in 0..3 {
            let status = fed
                .scp
                .wait(&format!("j{i}"), Duration::from_secs(30))
                .unwrap();
            assert_eq!(status, JobStatus::Finished);
        }
        fed.shutdown();
    }

    #[test]
    fn duplicate_job_id_rejected() {
        let fed = fast_cfgs(FederationBuilder::new("t").sites(1))
            .build(Arc::new(SpinApp))
            .unwrap();
        fed.scp.submit(JobSpec::new("dup", "x")).unwrap();
        assert!(fed.scp.submit(JobSpec::new("dup", "x")).is_err());
        fed.scp.abort("dup").unwrap();
        fed.shutdown();
    }

    #[test]
    fn remote_admin_submit_requires_auth() {
        let fed = fast_cfgs(FederationBuilder::new("t").sites(1))
            .build(Arc::new(DoubleApp))
            .unwrap();
        // A rogue messenger on a site's fabric submitting without admin
        // credentials must be denied by the SCP's authorizer.
        let msgr = crate::flare::reliable::Messenger::spawn(
            fed.ccps[0].fabric.clone() as Arc<dyn crate::flare::fabric::Fabric>,
            "site-1:rogue",
        )
        .unwrap();
        let res = msgr.request(
            address::SERVER,
            topics::SUBMIT,
            JobSpec::new("sneak", "double").encode(),
            RetryPolicy::fast(),
        );
        assert!(res.is_err(), "unauthenticated submit must fail");

        // A *site* kit is authenticated but not authorized to submit.
        let site_headers = vec![
            ("principal".to_string(), "site-1".to_string()),
            ("role".to_string(), "site".to_string()),
            (
                "token".to_string(),
                Provisioner::new("t", b"flarelink-project-secret")
                    .provision("site-1", Role::Site, "")
                    .token,
            ),
        ];
        let res = msgr.request_with_headers(
            address::SERVER,
            topics::SUBMIT,
            JobSpec::new("sneak2", "double").encode(),
            site_headers,
            RetryPolicy::fast(),
        );
        assert!(res.is_err(), "site role must not submit jobs");
        fed.shutdown();
    }

    #[test]
    fn remote_admin_submit_with_kit_works() {
        use crate::flare::job::JobStatus;
        let fed = fast_cfgs(FederationBuilder::new("t").sites(1))
            .build(Arc::new(DoubleApp))
            .unwrap();
        // An admin console attached to a site's fabric submits remotely
        // with its startup-kit credentials.
        let msgr = crate::flare::reliable::Messenger::spawn(
            fed.ccps[0].fabric.clone() as Arc<dyn crate::flare::fabric::Fabric>,
            "site-1:admin-console",
        )
        .unwrap();
        let spec = JobSpec::new("remote", "double")
            .with_config(Json::obj(vec![("rounds", Json::num(1))]));
        let headers = vec![
            ("principal".to_string(), fed.admin_kit.name.clone()),
            ("role".to_string(), "admin".to_string()),
            ("token".to_string(), fed.admin_kit.token.clone()),
        ];
        let rep = msgr
            .request_with_headers(
                address::SERVER,
                topics::SUBMIT,
                spec.encode(),
                headers.clone(),
                RetryPolicy::fast(),
            )
            .unwrap();
        assert_eq!(rep.payload, b"remote");
        let status = fed.scp.wait("remote", Duration::from_secs(20)).unwrap();
        assert_eq!(status, JobStatus::Finished);

        // Remote list with the same credentials.
        let rep = msgr
            .request_with_headers(
                address::SERVER,
                topics::LIST,
                Vec::new(),
                headers,
                RetryPolicy::fast(),
            )
            .unwrap();
        let listed = Json::parse(std::str::from_utf8(&rep.payload).unwrap()).unwrap();
        assert_eq!(listed.as_arr().unwrap().len(), 1);
        fed.shutdown();
    }
}
