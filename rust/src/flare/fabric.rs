//! Message fabric: delivers [`Envelope`]s between *cells* (addressable
//! mailboxes) across transports.
//!
//! Topology per the paper's §3.1: every site holds ONE link to the SCP;
//! all inter-cell traffic relays through the SCP by default. When network
//! policy permits, a *direct* site↔site link can be installed on the
//! client fabric and traffic between those sites bypasses the server
//! ([`CcpFabric::add_direct`]) — the paper's P2P mode.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::proto::{address, Envelope};
use crate::telemetry;
use crate::transport::{Endpoint, TransportError};

#[derive(Debug)]
pub enum FabricError {
    NoRoute(String),
    DuplicateCell(String),
    Transport(TransportError),
    Shutdown,
}

impl std::fmt::Display for FabricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FabricError::NoRoute(site) => write!(f, "fabric: no route to site '{site}'"),
            FabricError::DuplicateCell(cell) => {
                write!(f, "fabric: cell '{cell}' already registered")
            }
            FabricError::Transport(e) => write!(f, "fabric: transport: {e}"),
            FabricError::Shutdown => write!(f, "fabric: shut down"),
        }
    }
}

impl std::error::Error for FabricError {}

impl From<TransportError> for FabricError {
    fn from(e: TransportError) -> Self {
        FabricError::Transport(e)
    }
}

/// Receiving side of a registered cell.
pub struct Mailbox {
    pub address: String,
    rx: Receiver<Envelope>,
}

impl Mailbox {
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Envelope> {
        self.rx.recv_timeout(timeout).ok()
    }

    pub fn try_recv(&self) -> Option<Envelope> {
        self.rx.try_recv().ok()
    }
}

/// Next process-wide unique message id.
static NEXT_MSG_ID: AtomicU64 = AtomicU64::new(1);

pub fn next_msg_id() -> u64 {
    NEXT_MSG_ID.fetch_add(1, Ordering::Relaxed)
}

pub trait Fabric: Send + Sync {
    /// Route `env` toward its destination cell.
    fn send(&self, env: Envelope) -> Result<(), FabricError>;
    /// Register a local cell and obtain its mailbox.
    fn register(&self, address: &str) -> Result<Mailbox, FabricError>;
    fn unregister(&self, address: &str);
    /// The site this fabric belongs to ("server" for the SCP).
    fn local_site(&self) -> &str;
}

/// Cells registered in this process + helper to deliver locally.
#[derive(Default)]
struct CellTable {
    cells: Mutex<HashMap<String, Sender<Envelope>>>,
}

impl CellTable {
    fn register(&self, address: &str) -> Result<Mailbox, FabricError> {
        let mut cells = self.cells.lock().unwrap();
        if cells.contains_key(address) {
            return Err(FabricError::DuplicateCell(address.to_string()));
        }
        let (tx, rx) = channel();
        cells.insert(address.to_string(), tx);
        Ok(Mailbox {
            address: address.to_string(),
            rx,
        })
    }

    fn unregister(&self, address: &str) {
        self.cells.lock().unwrap().remove(address);
    }

    /// Deliver to a local cell; silently drops for unknown cells (the
    /// reliable layer's retries handle races around cell creation).
    fn deliver(&self, env: Envelope) {
        let cells = self.cells.lock().unwrap();
        if let Some(tx) = cells.get(&env.destination) {
            let _ = tx.send(env);
        } else {
            telemetry::bump("fabric.dropped_no_cell", 1);
            log::debug!("no local cell {}, dropping {:?}", env.destination, env.kind);
        }
    }
}

fn spawn_router(
    name: String,
    ep: Arc<dyn Endpoint>,
    shutdown: Arc<AtomicBool>,
    route: impl Fn(Envelope) + Send + 'static,
) {
    std::thread::Builder::new()
        .name(name)
        .spawn(move || loop {
            if shutdown.load(Ordering::Acquire) {
                return;
            }
            match ep.recv_timeout(Duration::from_millis(50)) {
                Ok(frame) => match Envelope::decode(&frame) {
                    Ok(env) => route(env),
                    Err(e) => {
                        telemetry::bump("fabric.bad_frame", 1);
                        log::warn!("undecodable frame: {e}");
                    }
                },
                Err(TransportError::Timeout) => continue,
                Err(_) => return, // closed
            }
        })
        .expect("spawn router");
}

// ---------------------------------------------------------------------------
// SCP fabric (server side)
// ---------------------------------------------------------------------------

pub struct ScpFabric {
    cells: Arc<CellTable>,
    links: Arc<Mutex<HashMap<String, Arc<dyn Endpoint>>>>,
    shutdown: Arc<AtomicBool>,
}

impl Default for ScpFabric {
    fn default() -> Self {
        Self::new()
    }
}

impl ScpFabric {
    pub fn new() -> Self {
        Self {
            cells: Arc::new(CellTable::default()),
            links: Arc::new(Mutex::new(HashMap::new())),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Attach a site's uplink endpoint and start routing its frames.
    pub fn add_site_link(&self, site: &str, ep: Arc<dyn Endpoint>) {
        self.links.lock().unwrap().insert(site.to_string(), ep.clone());
        let cells = self.cells.clone();
        let links = self.links.clone();
        let shutdown = self.shutdown.clone();
        spawn_router(
            format!("scp-router-{site}"),
            ep,
            self.shutdown.clone(),
            move |env| route_on_server(&cells, &links, &shutdown, env),
        );
    }

    pub fn remove_site_link(&self, site: &str) {
        if let Some(ep) = self.links.lock().unwrap().remove(site) {
            ep.close();
        }
    }

    pub fn connected_sites(&self) -> Vec<String> {
        let mut v: Vec<String> = self.links.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for (_, ep) in self.links.lock().unwrap().iter() {
            ep.close();
        }
    }
}

fn route_on_server(
    cells: &CellTable,
    links: &Mutex<HashMap<String, Arc<dyn Endpoint>>>,
    shutdown: &AtomicBool,
    env: Envelope,
) {
    if shutdown.load(Ordering::Acquire) {
        return;
    }
    let dest_site = address::site_of(&env.destination).to_string();
    if dest_site == address::SERVER {
        cells.deliver(env);
        return;
    }
    // Relay toward the destination site (the paper's default path: all
    // job-process traffic flows through the SCP).
    let ep = links.lock().unwrap().get(&dest_site).cloned();
    match ep {
        Some(ep) => {
            telemetry::bump("fabric.scp_relayed", 1);
            telemetry::bump("fabric.scp_relayed_bytes", env.payload.len() as i64);
            if let Err(e) = ep.send(env.encode()) {
                telemetry::bump("fabric.relay_failed", 1);
                log::warn!("relay to {dest_site} failed: {e}");
            }
        }
        None => {
            telemetry::bump("fabric.no_route", 1);
            log::debug!("no route to site {dest_site}");
        }
    }
}

impl Fabric for ScpFabric {
    fn send(&self, env: Envelope) -> Result<(), FabricError> {
        if self.shutdown.load(Ordering::Acquire) {
            return Err(FabricError::Shutdown);
        }
        let dest_site = address::site_of(&env.destination).to_string();
        if dest_site == address::SERVER {
            self.cells.deliver(env);
            return Ok(());
        }
        let ep = self.links.lock().unwrap().get(&dest_site).cloned();
        match ep {
            Some(ep) => {
                ep.send(env.encode())?;
                Ok(())
            }
            None => Err(FabricError::NoRoute(dest_site)),
        }
    }

    fn register(&self, address: &str) -> Result<Mailbox, FabricError> {
        self.cells.register(address)
    }

    fn unregister(&self, address: &str) {
        self.cells.unregister(address);
    }

    fn local_site(&self) -> &str {
        address::SERVER
    }
}

// ---------------------------------------------------------------------------
// CCP fabric (client site)
// ---------------------------------------------------------------------------

pub struct CcpFabric {
    site: String,
    cells: Arc<CellTable>,
    uplink: Arc<dyn Endpoint>,
    /// site -> direct P2P link (bypasses the SCP when present).
    directs: Arc<Mutex<HashMap<String, Arc<dyn Endpoint>>>>,
    shutdown: Arc<AtomicBool>,
}

impl CcpFabric {
    pub fn new(site: &str, uplink: Arc<dyn Endpoint>) -> Arc<Self> {
        let fabric = Arc::new(Self {
            site: site.to_string(),
            cells: Arc::new(CellTable::default()),
            uplink: uplink.clone(),
            directs: Arc::new(Mutex::new(HashMap::new())),
            shutdown: Arc::new(AtomicBool::new(false)),
        });
        let cells = fabric.cells.clone();
        spawn_router(
            format!("ccp-router-{site}"),
            uplink,
            fabric.shutdown.clone(),
            move |env| cells.deliver(env),
        );
        fabric
    }

    /// Install a direct link to a peer site (paper's P2P mode). Frames
    /// arriving on it are delivered locally like uplink frames.
    pub fn add_direct(&self, peer_site: &str, ep: Arc<dyn Endpoint>) {
        self.directs
            .lock()
            .unwrap()
            .insert(peer_site.to_string(), ep.clone());
        let cells = self.cells.clone();
        spawn_router(
            format!("ccp-direct-{}-{}", self.site, peer_site),
            ep,
            self.shutdown.clone(),
            move |env| cells.deliver(env),
        );
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.uplink.close();
        for (_, ep) in self.directs.lock().unwrap().iter() {
            ep.close();
        }
    }
}

impl Fabric for CcpFabric {
    fn send(&self, env: Envelope) -> Result<(), FabricError> {
        if self.shutdown.load(Ordering::Acquire) {
            return Err(FabricError::Shutdown);
        }
        let dest_site = address::site_of(&env.destination).to_string();
        if dest_site == self.site {
            self.cells.deliver(env);
            return Ok(());
        }
        if let Some(direct) = self.directs.lock().unwrap().get(&dest_site) {
            telemetry::bump("fabric.direct_sent", 1);
            direct.send(env.encode())?;
            return Ok(());
        }
        // Default: everything goes to the SCP, which relays if needed.
        self.uplink.send(env.encode())?;
        Ok(())
    }

    fn register(&self, address: &str) -> Result<Mailbox, FabricError> {
        self.cells.register(address)
    }

    fn unregister(&self, address: &str) {
        self.cells.unregister(address);
    }

    fn local_site(&self) -> &str {
        &self.site
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::MsgKind;
    use crate::transport::inproc;

    fn wire_site(scp: &ScpFabric, site: &str) -> Arc<CcpFabric> {
        let (server_end, client_end) = inproc::pair(address::SERVER, site);
        scp.add_site_link(site, Arc::new(server_end));
        CcpFabric::new(site, Arc::new(client_end))
    }

    fn env(src: &str, dst: &str) -> Envelope {
        let mut e = Envelope::new(MsgKind::Event, src, dst, "t");
        e.id = next_msg_id();
        e
    }

    #[test]
    fn client_to_server_cell() {
        let scp = ScpFabric::new();
        let mb = scp.register("server").unwrap();
        let ccp = wire_site(&scp, "site-1");
        ccp.send(env("site-1", "server")).unwrap();
        let got = mb.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.source, "site-1");
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn server_to_client_cell() {
        let scp = ScpFabric::new();
        let ccp = wire_site(&scp, "site-1");
        let mb = ccp.register("site-1:j1").unwrap();
        scp.send(env("server:j1", "site-1:j1")).unwrap();
        let got = mb.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.destination, "site-1:j1");
        scp.shutdown();
        ccp.shutdown();
    }

    #[test]
    fn site_to_site_relays_through_scp() {
        let scp = ScpFabric::new();
        let ccp1 = wire_site(&scp, "site-1");
        let ccp2 = wire_site(&scp, "site-2");
        let mb = ccp2.register("site-2:j1").unwrap();
        telemetry::reset_counters();
        ccp1.send(env("site-1:j1", "site-2:j1")).unwrap();
        let got = mb.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.source, "site-1:j1");
        assert!(telemetry::counter("fabric.scp_relayed").load(std::sync::atomic::Ordering::Relaxed) >= 1);
        scp.shutdown();
        ccp1.shutdown();
        ccp2.shutdown();
    }

    #[test]
    fn direct_link_bypasses_scp() {
        let scp = ScpFabric::new();
        let ccp1 = wire_site(&scp, "site-1");
        let ccp2 = wire_site(&scp, "site-2");
        let (e1, e2) = inproc::pair("site-1", "site-2");
        ccp1.add_direct("site-2", Arc::new(e1));
        ccp2.add_direct("site-1", Arc::new(e2));
        let mb = ccp2.register("site-2:j1").unwrap();
        telemetry::reset_counters();
        ccp1.send(env("site-1:j1", "site-2:j1")).unwrap();
        let got = mb.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.source, "site-1:j1");
        assert_eq!(
            telemetry::counter("fabric.scp_relayed").load(std::sync::atomic::Ordering::Relaxed),
            0,
            "must not relay through SCP"
        );
        scp.shutdown();
        ccp1.shutdown();
        ccp2.shutdown();
    }

    #[test]
    fn no_route_errors() {
        let scp = ScpFabric::new();
        assert!(matches!(
            scp.send(env("server", "site-9:j")),
            Err(FabricError::NoRoute(_))
        ));
    }

    #[test]
    fn duplicate_cell_rejected() {
        let scp = ScpFabric::new();
        let _mb = scp.register("server:x").unwrap();
        assert!(matches!(
            scp.register("server:x"),
            Err(FabricError::DuplicateCell(_))
        ));
    }

    #[test]
    fn unknown_local_cell_drops_not_panics() {
        let scp = ScpFabric::new();
        scp.send(env("server", "server:ghost")).unwrap();
    }

    #[test]
    fn unregister_frees_address() {
        let scp = ScpFabric::new();
        let mb = scp.register("server:y").unwrap();
        drop(mb);
        scp.unregister("server:y");
        assert!(scp.register("server:y").is_ok());
    }
}
