//! Server Control Process (paper §3.1 / Fig. 2): owns the site registry,
//! the multi-job scheduler, job deployment/monitoring/abort, the metric
//! store, and the server-side job runners. One SCP per federation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::flare::auth::{Action, Authorizer};
use crate::flare::fabric::{Fabric, ScpFabric};
use crate::flare::job::{AppFactory, JobCtx, JobId, JobSpec, JobStatus};
use crate::flare::provision::Role;
use crate::flare::reliable::{Messenger, RetryPolicy};
use crate::flare::scheduler::Scheduler;
use crate::flare::tracking::{MetricEvent, MetricStore, SummaryWriter, METRICS_TOPIC};
use crate::proto::{address, Envelope};
use crate::util::bytes::{Reader, Writer};
use crate::util::json::Json;

/// Control topics understood by the SCP's `"server"` cell.
pub mod topics {
    pub const REGISTER: &str = "ccp.register";
    pub const HEARTBEAT: &str = "ccp.heartbeat";
    pub const SITE_DONE: &str = "job.site_done";
    pub const SUBMIT: &str = "admin.submit";
    pub const ABORT: &str = "admin.abort";
    pub const LIST: &str = "admin.list";
    pub const DEPLOY: &str = "job.deploy";
    pub const STOP: &str = "job.stop";
}

#[derive(Clone, Debug)]
pub struct ScpConfig {
    /// Max simultaneously running jobs (0 = unlimited).
    pub max_concurrent_jobs: usize,
    /// Slot capacity granted to each registering site.
    pub default_site_slots: u32,
    /// Sites silent for longer than this are considered dead.
    pub heartbeat_timeout: Duration,
    /// Reliable-messaging policy for control traffic.
    pub policy: RetryPolicy,
    /// Scheduler poll interval.
    pub tick: Duration,
}

impl Default for ScpConfig {
    fn default() -> Self {
        Self {
            max_concurrent_jobs: 0,
            default_site_slots: 4,
            heartbeat_timeout: Duration::from_secs(10),
            policy: RetryPolicy::default(),
            tick: Duration::from_millis(20),
        }
    }
}

struct SiteInfo {
    #[allow(dead_code)]
    name: String,
    last_seen: Instant,
}

struct JobState {
    spec: JobSpec,
    status: JobStatus,
    participants: Vec<String>,
    abort: Arc<AtomicBool>,
    error: Option<String>,
    /// Per-site completion reports.
    site_done: HashMap<String, bool>,
}

pub struct Scp {
    pub fabric: Arc<ScpFabric>,
    control: Arc<Messenger>,
    authorizer: Arc<Authorizer>,
    pub metrics: Arc<MetricStore>,
    cfg: ScpConfig,
    scheduler: Mutex<Scheduler>,
    jobs: Mutex<HashMap<JobId, JobState>>,
    sites: Mutex<HashMap<String, SiteInfo>>,
    app_factory: Arc<dyn AppFactory>,
    compute: Option<crate::runtime::ComputeHandle>,
    shutdown: Arc<AtomicBool>,
}

impl Scp {
    pub fn start(
        fabric: Arc<ScpFabric>,
        authorizer: Arc<Authorizer>,
        app_factory: Arc<dyn AppFactory>,
        compute: Option<crate::runtime::ComputeHandle>,
        cfg: ScpConfig,
    ) -> anyhow::Result<Arc<Scp>> {
        let control = Messenger::spawn(fabric.clone() as Arc<dyn Fabric>, address::SERVER)?;
        let scp = Arc::new(Scp {
            fabric,
            control: control.clone(),
            authorizer,
            metrics: MetricStore::new(),
            scheduler: Mutex::new(Scheduler::new(cfg.max_concurrent_jobs)),
            cfg,
            jobs: Mutex::new(HashMap::new()),
            sites: Mutex::new(HashMap::new()),
            app_factory,
            compute,
            shutdown: Arc::new(AtomicBool::new(false)),
        });

        // Control-plane request handler.
        let me = scp.clone();
        control.set_handler(Arc::new(move |env| me.handle_control(env)));
        // Metric events + heartbeats.
        let me = scp.clone();
        control.set_event_handler(Arc::new(move |env| me.handle_event(env)));

        // Scheduler loop.
        let me = scp.clone();
        std::thread::Builder::new()
            .name("scp-scheduler".into())
            .spawn(move || me.scheduler_loop())?;
        Ok(scp)
    }

    // ------------------------------------------------------------------
    // Admin API (local calls; remote admin goes through handle_control)
    // ------------------------------------------------------------------

    /// Submit a job (FLARE's `nvflare job submit`).
    pub fn submit(&self, spec: JobSpec) -> anyhow::Result<JobId> {
        let id = spec.id.clone();
        {
            let jobs = self.jobs.lock().unwrap();
            if jobs.contains_key(&id) {
                anyhow::bail!("job id '{id}' already exists");
            }
        }
        let participants = self.scheduler.lock().unwrap().participants(&spec);
        self.jobs.lock().unwrap().insert(
            id.clone(),
            JobState {
                spec: spec.clone(),
                status: JobStatus::Queued,
                participants,
                abort: Arc::new(AtomicBool::new(false)),
                error: None,
                site_done: HashMap::new(),
            },
        );
        self.scheduler.lock().unwrap().enqueue(spec);
        log::info!("job submitted: {id}");
        Ok(id)
    }

    pub fn status(&self, job_id: &str) -> Option<JobStatus> {
        self.jobs.lock().unwrap().get(job_id).map(|j| j.status)
    }

    pub fn job_error(&self, job_id: &str) -> Option<String> {
        self.jobs.lock().unwrap().get(job_id).and_then(|j| j.error.clone())
    }

    pub fn list(&self) -> Vec<(JobId, JobStatus)> {
        let mut v: Vec<(JobId, JobStatus)> = self
            .jobs
            .lock()
            .unwrap()
            .iter()
            .map(|(id, st)| (id.clone(), st.status))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn abort(&self, job_id: &str) -> anyhow::Result<()> {
        let mut jobs = self.jobs.lock().unwrap();
        let st = jobs
            .get_mut(job_id)
            .ok_or_else(|| anyhow::anyhow!("no such job {job_id}"))?;
        match st.status {
            JobStatus::Queued => {
                self.scheduler.lock().unwrap().dequeue(job_id);
                st.status = JobStatus::Aborted;
            }
            JobStatus::Deploying | JobStatus::Running => {
                st.abort.store(true, Ordering::Release);
                st.status = JobStatus::Aborted;
                let participants = st.participants.clone();
                drop(jobs);
                self.notify_sites_stop(job_id, &participants);
                let mut jobs = self.jobs.lock().unwrap();
                if let Some(st) = jobs.get_mut(job_id) {
                    let spec = st.spec.clone();
                    drop(jobs);
                    self.scheduler.lock().unwrap().release(&spec);
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Block until the job reaches a terminal state (or timeout).
    pub fn wait(&self, job_id: &str, timeout: Duration) -> Option<JobStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.status(job_id) {
                Some(s) if s.is_terminal() => return Some(s),
                None => return None,
                _ => {}
            }
            if Instant::now() >= deadline {
                return self.status(job_id);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    pub fn registered_sites(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sites.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.control.shutdown();
        self.fabric.shutdown();
    }

    // ------------------------------------------------------------------
    // Control-plane handling
    // ------------------------------------------------------------------

    fn handle_control(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        match env.topic.as_str() {
            topics::REGISTER => self.on_register(env),
            topics::SITE_DONE => self.on_site_done(env),
            topics::SUBMIT => self.on_remote_submit(env),
            topics::ABORT => self.on_remote_abort(env),
            topics::LIST => self.on_remote_list(env),
            other => anyhow::bail!("scp: unknown control topic '{other}'"),
        }
    }

    fn on_register(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        let mut r = Reader::new(&env.payload);
        let name = r.str()?.to_string();
        let token = r.str()?.to_string();
        let slots = r.u32()?;
        self.authorizer
            .authenticate(&name, Role::Site, &token)
            .map_err(|e| anyhow::anyhow!("registration rejected: {e}"))?;
        self.authorizer.check(&name, Action::RegisterSite)?;
        let slots = if slots == 0 {
            self.cfg.default_site_slots
        } else {
            slots
        };
        self.sites.lock().unwrap().insert(
            name.clone(),
            SiteInfo {
                name: name.clone(),
                last_seen: Instant::now(),
            },
        );
        self.scheduler.lock().unwrap().set_site_capacity(&name, slots);
        log::info!("site registered: {name} ({slots} slots)");
        Ok(b"ok".to_vec())
    }

    fn on_site_done(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        let mut r = Reader::new(&env.payload);
        let job_id = r.str()?.to_string();
        let site = r.str()?.to_string();
        let ok = r.u8()? == 1;
        let err = r.str()?.to_string();
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(st) = jobs.get_mut(&job_id) {
            st.site_done.insert(site.clone(), ok);
            if !ok && st.error.is_none() {
                st.error = Some(format!("site {site}: {err}"));
            }
        }
        Ok(b"ok".to_vec())
    }

    fn authorize_remote(&self, env: &Envelope, action: Action) -> anyhow::Result<()> {
        let name = env
            .header("principal")
            .ok_or_else(|| anyhow::anyhow!("missing principal header"))?;
        let role = env
            .header("role")
            .and_then(Role::parse)
            .ok_or_else(|| anyhow::anyhow!("missing/bad role header"))?;
        let token = env
            .header("token")
            .ok_or_else(|| anyhow::anyhow!("missing token header"))?;
        self.authorizer.authenticate(name, role, token)?;
        self.authorizer.check(name, action)?;
        Ok(())
    }

    fn on_remote_submit(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        self.authorize_remote(env, Action::SubmitJob)?;
        let spec = JobSpec::decode(&env.payload)?;
        let id = self.submit(spec)?;
        Ok(id.into_bytes())
    }

    fn on_remote_abort(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        self.authorize_remote(env, Action::AbortJob)?;
        let job_id = std::str::from_utf8(&env.payload)?;
        self.abort(job_id)?;
        Ok(b"ok".to_vec())
    }

    fn on_remote_list(&self, env: &Envelope) -> anyhow::Result<Vec<u8>> {
        self.authorize_remote(env, Action::ListJobs)?;
        let arr = self
            .list()
            .into_iter()
            .map(|(id, st)| {
                Json::obj(vec![
                    ("id", Json::str(id)),
                    ("status", Json::str(st.as_str())),
                ])
            })
            .collect();
        Ok(Json::Arr(arr).to_string().into_bytes())
    }

    fn handle_event(&self, env: &Envelope) {
        match env.topic.as_str() {
            METRICS_TOPIC => {
                if let Ok(ev) = MetricEvent::decode(&env.payload) {
                    self.metrics.record(ev);
                }
            }
            topics::HEARTBEAT => {
                let site = env.source.clone();
                if let Some(info) = self.sites.lock().unwrap().get_mut(&site) {
                    info.last_seen = Instant::now();
                }
            }
            _ => {}
        }
    }

    // ------------------------------------------------------------------
    // Scheduling + deployment
    // ------------------------------------------------------------------

    fn scheduler_loop(self: Arc<Self>) {
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            self.check_heartbeats();
            let to_deploy = self.scheduler.lock().unwrap().schedule();
            for spec in to_deploy {
                let me = self.clone();
                std::thread::Builder::new()
                    .name(format!("scp-deploy-{}", spec.id))
                    .spawn(move || me.deploy_job(spec))
                    .expect("spawn deploy");
            }
            std::thread::sleep(self.cfg.tick);
        }
    }

    fn check_heartbeats(&self) {
        let timeout = self.cfg.heartbeat_timeout;
        let mut dead = Vec::new();
        {
            let sites = self.sites.lock().unwrap();
            for (name, info) in sites.iter() {
                if info.last_seen.elapsed() > timeout {
                    dead.push(name.clone());
                }
            }
        }
        for site in dead {
            log::warn!("site {site} missed heartbeats; deregistering");
            self.sites.lock().unwrap().remove(&site);
            self.scheduler.lock().unwrap().remove_site(&site);
            self.fabric.remove_site_link(&site);
            // Abort running jobs that include this site.
            let affected: Vec<JobId> = self
                .jobs
                .lock()
                .unwrap()
                .iter()
                .filter(|(_, st)| {
                    !st.status.is_terminal() && st.participants.contains(&site)
                })
                .map(|(id, _)| id.clone())
                .collect();
            for id in affected {
                let _ = self.abort(&id);
                if let Some(st) = self.jobs.lock().unwrap().get_mut(&id) {
                    st.status = JobStatus::Failed;
                    st.error = Some(format!("site {site} lost"));
                }
            }
        }
    }

    fn deploy_job(self: Arc<Self>, spec: JobSpec) {
        let job_id = spec.id.clone();
        let participants = self.scheduler.lock().unwrap().participants(&spec);
        {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(st) = jobs.get_mut(&job_id) else { return };
            if st.status != JobStatus::Queued {
                return; // aborted while queued
            }
            st.status = JobStatus::Deploying;
            st.participants = participants.clone();
        }
        log::info!("deploying job {job_id} to {participants:?}");

        // Send deploy to every participant CCP (reliable).
        let mut deploy_payload = Writer::new();
        deploy_payload.bytes(&spec.encode());
        let mut participants_w = Writer::new();
        participants_w.u32(participants.len() as u32);
        for p in &participants {
            participants_w.str(p);
        }
        deploy_payload.bytes(&participants_w.into_bytes());
        let deploy_payload = deploy_payload.into_bytes();

        for site in &participants {
            match self.control.request(
                site,
                topics::DEPLOY,
                deploy_payload.clone(),
                self.cfg.policy,
            ) {
                Ok(_) => {}
                Err(e) => {
                    log::error!("deploy of {job_id} to {site} failed: {e}");
                    self.fail_job(&job_id, &format!("deploy to {site}: {e}"));
                    return;
                }
            }
        }

        // Run the server-side app in this thread; its return ends the job.
        let (abort, config) = {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(st) = jobs.get_mut(&job_id) else { return };
            st.status = JobStatus::Running;
            (st.abort.clone(), st.spec.config.clone())
        };
        let cell = address::job_cell(address::SERVER, &job_id);
        let messenger =
            match Messenger::spawn(self.fabric.clone() as Arc<dyn Fabric>, &cell) {
                Ok(m) => m,
                Err(e) => {
                    self.fail_job(&job_id, &format!("server cell: {e}"));
                    return;
                }
            };
        let ctx = JobCtx {
            job_id: job_id.clone(),
            site: address::SERVER.to_string(),
            participants: participants.clone(),
            messenger: messenger.clone(),
            config,
            tracker: SummaryWriter::new(messenger.clone(), &job_id, address::SERVER),
            compute: self.compute.clone(),
            site_token: String::new(),
            authenticator: Some(self.authorizer.clone()),
            abort: abort.clone(),
        };
        let result = self.app_factory.run_server(ctx);
        messenger.shutdown();

        // Tell sites to tear down their job processes.
        self.notify_sites_stop(&job_id, &participants);

        let mut jobs = self.jobs.lock().unwrap();
        if let Some(st) = jobs.get_mut(&job_id) {
            if !st.status.is_terminal() {
                match result {
                    Ok(()) => st.status = JobStatus::Finished,
                    Err(e) => {
                        st.status = JobStatus::Failed;
                        st.error = Some(e.to_string());
                    }
                }
                let spec = st.spec.clone();
                drop(jobs);
                self.scheduler.lock().unwrap().release(&spec);
            }
        }
        log::info!("job {job_id} done");
    }

    fn fail_job(&self, job_id: &str, error: &str) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(st) = jobs.get_mut(job_id) {
            if !st.status.is_terminal() {
                st.status = JobStatus::Failed;
                st.error = Some(error.to_string());
                let spec = st.spec.clone();
                drop(jobs);
                self.scheduler.lock().unwrap().release(&spec);
            }
        }
    }

    fn notify_sites_stop(&self, job_id: &str, participants: &[String]) {
        for site in participants {
            let _ = self.control.request(
                site,
                topics::STOP,
                job_id.as_bytes().to_vec(),
                RetryPolicy {
                    deadline: Duration::from_secs(2),
                    ..self.cfg.policy
                },
            );
        }
    }
}
