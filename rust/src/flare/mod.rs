//! FLARE-analogue runtime (paper §3.1, §4.1): multi-job control plane
//! (SCP/CCP), reliable messaging, provisioning + authz, metric streaming,
//! chunked large-message streaming, and federation assembly.

pub mod auth;
pub mod ccp;
pub mod deploy;
pub mod fabric;
pub mod job;
pub mod provision;
pub mod reliable;
pub mod scheduler;
pub mod scp;
pub mod sim;
pub mod streaming;
pub mod tracking;

pub use fabric::{CcpFabric, Fabric, ScpFabric};
pub use job::{AppFactory, JobCtx, JobSpec, JobStatus};
pub use reliable::{Messenger, ReliableError, RetryPolicy};
pub use sim::{Federation, FederationBuilder};
