//! Experiment harness shared by examples, benches, and integration
//! tests: one-call wrappers that run a full FL job natively (Fig. 5a) or
//! inside a FLARE federation (Fig. 5b) and hand back the history +
//! streamed metrics.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::bridge::FlowerBridgeApp;
use crate::flare::reliable::RetryPolicy;
use crate::flare::sim::FederationBuilder;
use crate::flare::{JobSpec, JobStatus};
use crate::flower::serverapp::History;
use crate::runtime::ComputeHandle;
use crate::train::{run_native_fl, FlJobConfig, TrainedFlowerApp};

/// Options for a bridged run.
#[derive(Clone, Debug)]
pub struct BridgedRunOpts {
    pub drop_prob: f64,
    pub latency: Duration,
    pub fault_seed: u64,
    pub policy: RetryPolicy,
    pub job_id: String,
    pub timeout: Duration,
}

impl Default for BridgedRunOpts {
    fn default() -> Self {
        Self {
            drop_prob: 0.0,
            latency: Duration::ZERO,
            fault_seed: 7,
            policy: RetryPolicy::fast(),
            job_id: "flower-job".into(),
            timeout: Duration::from_secs(1800),
        }
    }
}

/// Result of a bridged run: the Flower history plus the FLARE-side
/// metric export (Fig. 6 data when `cfg.track`).
pub struct BridgedRunResult {
    pub history: History,
    pub metrics_tsv: String,
    /// (site, tag) -> series from the SCP metric store.
    pub metric_series: Vec<((String, String), Vec<(u64, f64)>)>,
}

/// Run the FL job natively (no FLARE) — the Fig. 5(a) path.
pub fn run_fl_native(cfg: &FlJobConfig, compute: ComputeHandle) -> anyhow::Result<History> {
    run_native_fl(cfg, compute)
}

/// Run the FL job inside a FLARE federation — the Fig. 5(b) path
/// (`nvflare job submit` equivalent).
pub fn run_fl_bridged(
    cfg: &FlJobConfig,
    compute: ComputeHandle,
    opts: &BridgedRunOpts,
) -> anyhow::Result<BridgedRunResult> {
    let captured: Arc<Mutex<Option<History>>> = Arc::new(Mutex::new(None));
    let c2 = captured.clone();
    let app = FlowerBridgeApp::new(Arc::new(TrainedFlowerApp {
        compute: compute.clone(),
    }))
    .with_policy(opts.policy)
    .with_history_sink(Arc::new(move |_, h| {
        *c2.lock().unwrap() = Some(h.clone());
    }));

    let fed = FederationBuilder::new("harness")
        .sites(cfg.clients)
        .faults(opts.drop_prob, opts.latency, opts.fault_seed)
        .retry_policy(opts.policy)
        .compute(compute)
        .build(Arc::new(app))?;

    let spec = JobSpec::new(&opts.job_id, "flower_bridge").with_config(cfg.to_json());
    fed.scp.submit(spec)?;
    let status = fed
        .scp
        .wait(&opts.job_id, opts.timeout)
        .ok_or_else(|| anyhow::anyhow!("job vanished"))?;
    anyhow::ensure!(
        status == JobStatus::Finished,
        "job {}: {} ({:?})",
        opts.job_id,
        status.as_str(),
        fed.scp.job_error(&opts.job_id)
    );

    let metrics_tsv = fed.scp.metrics.export_tsv(&opts.job_id);
    let metric_series = fed
        .scp
        .metrics
        .keys(&opts.job_id)
        .into_iter()
        .map(|(site, tag)| {
            let series = fed.scp.metrics.series(&opts.job_id, &site, &tag);
            ((site, tag), series)
        })
        .collect();
    fed.shutdown();

    let history = captured
        .lock()
        .unwrap()
        .take()
        .ok_or_else(|| anyhow::anyhow!("history sink never fired"))?;
    Ok(BridgedRunResult {
        history,
        metrics_tsv,
        metric_series,
    })
}

/// Ensure artifacts exist or exit with a friendly message (examples).
pub fn require_artifacts() -> ComputeHandle {
    if !crate::runtime::artifacts_available() {
        eprintln!("artifacts/ not found — run `make artifacts` first");
        std::process::exit(1);
    }
    crate::runtime::global_compute(compute_threads_from_env()).expect("compute service")
}

pub fn compute_threads_from_env() -> usize {
    std::env::var("FLARELINK_COMPUTE_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}
