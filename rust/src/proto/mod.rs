//! The FLARE message envelope: every frame on every transport is one
//! encoded [`Envelope`]. Addressing follows the paper's cell model —
//! control processes are `"server"` / `"<site>"`, job processes are
//! `"<site>:<job_id>"` ("Job Network" cells, §3.1).

use crate::util::bytes::{Reader, WireError, Writer};

/// Maximum header entries in one envelope. Checked BEFORE the count
/// sizes any allocation; the count itself travels as a u32 and is only
/// ever widened (u32 -> usize), never narrowed — wire-supplied lengths
/// must not truncate platform-dependently (see the codec-hardening
/// audit; string/payload lengths are bounded by
/// [`crate::util::bytes::MAX_FIELD`] inside the reader).
pub const MAX_ENVELOPE_HEADERS: usize = 1024;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// A request expecting a reply (reliable-messaging managed).
    Request = 0,
    /// Reply to a request (correlation_id = request id).
    Reply = 1,
    /// Transport-level acknowledgement that a request was received.
    Ack = 2,
    /// "Is the result for request <correlation_id> ready?" (§4.1 polling).
    Query = 3,
    /// Fire-and-forget event (metrics streaming, heartbeats).
    Event = 4,
}

impl MsgKind {
    fn from_u8(v: u8) -> Result<Self, WireError> {
        Ok(match v {
            0 => MsgKind::Request,
            1 => MsgKind::Reply,
            2 => MsgKind::Ack,
            3 => MsgKind::Query,
            4 => MsgKind::Event,
            other => return Err(WireError::BadTag(other)),
        })
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    /// Unique message id (per sender).
    pub id: u64,
    /// For Reply/Ack/Query: the id of the originating request; else 0.
    pub correlation_id: u64,
    pub kind: MsgKind,
    /// Source cell, e.g. "site-1:job-abc" or "server".
    pub source: String,
    /// Destination cell.
    pub destination: String,
    /// Application channel, e.g. "flower.frame", "job.deploy", "metrics".
    pub topic: String,
    /// Small string headers (auth token, run id, ...).
    pub headers: Vec<(String, String)>,
    /// Opaque application payload.
    pub payload: Vec<u8>,
}

impl Envelope {
    pub fn new(kind: MsgKind, source: &str, destination: &str, topic: &str) -> Self {
        Self {
            id: 0,
            correlation_id: 0,
            kind,
            source: source.to_string(),
            destination: destination.to_string(),
            topic: topic.to_string(),
            headers: Vec::new(),
            payload: Vec::new(),
        }
    }

    pub fn with_payload(mut self, payload: Vec<u8>) -> Self {
        self.payload = payload;
        self
    }

    pub fn with_header(mut self, k: &str, v: &str) -> Self {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }

    pub fn header(&self, k: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(hk, _)| hk == k)
            .map(|(_, v)| v.as_str())
    }

    /// Build the reply envelope for this request.
    pub fn reply_to(&self, payload: Vec<u8>) -> Envelope {
        Envelope {
            id: 0,
            correlation_id: self.id,
            kind: MsgKind::Reply,
            source: self.destination.clone(),
            destination: self.source.clone(),
            topic: self.topic.clone(),
            headers: Vec::new(),
            payload,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64 + self.payload.len());
        w.u64(self.id);
        w.u64(self.correlation_id);
        w.u8(self.kind as u8);
        w.str(&self.source);
        w.str(&self.destination);
        w.str(&self.topic);
        w.u32(self.headers.len() as u32);
        for (k, v) in &self.headers {
            w.str(k);
            w.str(v);
        }
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Envelope, WireError> {
        let mut r = Reader::new(buf);
        let id = r.u64()?;
        let correlation_id = r.u64()?;
        let kind = MsgKind::from_u8(r.u8()?)?;
        let source = r.str()?.to_string();
        let destination = r.str()?.to_string();
        let topic = r.str()?.to_string();
        let n_headers = r.u32()? as usize;
        if n_headers > MAX_ENVELOPE_HEADERS {
            return Err(WireError::TooLong {
                len: n_headers,
                limit: MAX_ENVELOPE_HEADERS,
            });
        }
        let mut headers = Vec::with_capacity(n_headers);
        for _ in 0..n_headers {
            let k = r.str()?.to_string();
            let v = r.str()?.to_string();
            headers.push((k, v));
        }
        let payload = r.bytes()?.to_vec();
        Ok(Envelope {
            id,
            correlation_id,
            kind,
            source,
            destination,
            topic,
            headers,
            payload,
        })
    }
}

/// Cell address helpers.
pub mod address {
    /// The server control process cell.
    pub const SERVER: &str = "server";

    /// Job cell on a site: `"<site>:<job_id>"`.
    pub fn job_cell(site: &str, job_id: &str) -> String {
        format!("{site}:{job_id}")
    }

    /// Split a cell address into (site, job). `"server"` → ("server", None).
    pub fn parse(cell: &str) -> (&str, Option<&str>) {
        match cell.split_once(':') {
            Some((site, job)) => (site, Some(job)),
            None => (cell, None),
        }
    }

    /// The site (routing key) of a cell address.
    pub fn site_of(cell: &str) -> &str {
        parse(cell).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Envelope {
        Envelope {
            id: 42,
            correlation_id: 7,
            kind: MsgKind::Request,
            source: "site-1:job-x".into(),
            destination: "server".into(),
            topic: "flower.frame".into(),
            headers: vec![("auth".into(), "tok".into()), ("run".into(), "1".into())],
            payload: vec![1, 2, 3, 255],
        }
    }

    #[test]
    fn roundtrip() {
        let e = sample();
        let buf = e.encode();
        assert_eq!(Envelope::decode(&buf).unwrap(), e);
    }

    #[test]
    fn roundtrip_empty_fields() {
        let e = Envelope::new(MsgKind::Event, "", "", "");
        assert_eq!(Envelope::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn all_kinds_roundtrip() {
        for kind in [
            MsgKind::Request,
            MsgKind::Reply,
            MsgKind::Ack,
            MsgKind::Query,
            MsgKind::Event,
        ] {
            let mut e = sample();
            e.kind = kind;
            assert_eq!(Envelope::decode(&e.encode()).unwrap().kind, kind);
        }
    }

    #[test]
    fn bad_kind_rejected() {
        let mut buf = sample().encode();
        buf[16] = 99; // kind byte follows two u64s
        assert!(Envelope::decode(&buf).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let buf = sample().encode();
        for cut in [0, 5, 17, buf.len() - 1] {
            assert!(Envelope::decode(&buf[..cut]).is_err(), "cut {}", cut);
        }
    }

    #[test]
    fn oversized_header_count_rejected() {
        // A hostile count must surface a typed error before it can size
        // an allocation.
        let mut w = Writer::new();
        w.u64(1);
        w.u64(0);
        w.u8(MsgKind::Event as u8);
        w.str("a");
        w.str("b");
        w.str("t");
        w.u32(u32::MAX);
        let err = Envelope::decode(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, WireError::TooLong { .. }), "{err:?}");
    }

    #[test]
    fn reply_to_swaps_addresses() {
        let mut req = sample();
        req.id = 1234;
        let rep = req.reply_to(vec![9]);
        assert_eq!(rep.kind, MsgKind::Reply);
        assert_eq!(rep.correlation_id, 1234);
        assert_eq!(rep.source, "server");
        assert_eq!(rep.destination, "site-1:job-x");
        assert_eq!(rep.payload, vec![9]);
    }

    #[test]
    fn header_lookup() {
        let e = sample();
        assert_eq!(e.header("auth"), Some("tok"));
        assert_eq!(e.header("missing"), None);
    }

    #[test]
    fn address_helpers() {
        assert_eq!(address::job_cell("site-1", "j9"), "site-1:j9");
        assert_eq!(address::parse("site-1:j9"), ("site-1", Some("j9")));
        assert_eq!(address::parse("server"), ("server", None));
        assert_eq!(address::site_of("site-2:abc"), "site-2");
    }
}
