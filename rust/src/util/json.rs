//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! vendor set). Supports the full JSON grammar minus exotic number forms;
//! used for `artifacts/manifest.json`, federation/job configs, and metric
//! export.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---------------- construction helpers ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

// ---------------- serialize ----------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{}", b),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{}", n)
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", x)?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{}", v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{}", c)?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(j.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"},"n":-7}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"\\x\"", "{} x"] {
            assert!(Json::parse(bad).is_err(), "should reject {:?}", bad);
        }
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn accessor_conversions() {
        let j = Json::parse(r#"{"n":3,"f":3.5,"s":"x","b":true}"#).unwrap();
        assert_eq!(j.get("n").as_u64(), Some(3));
        assert_eq!(j.get("n").as_usize(), Some(3));
        assert_eq!(j.get("f").as_u64(), None);
        assert_eq!(j.get("b").as_bool(), Some(true));
        assert_eq!(j.get("missing"), &Json::Null);
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::Str("weird \"\\\n\t\u{1} chars".into());
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }
}
