//! Wire-format primitives: a little-endian writer/reader pair used by the
//! transport frames, the FLARE envelope codec, and the Flower message
//! protocol. All multi-byte integers are little-endian; byte strings and
//! vectors are u32-length-prefixed.

use byteorder::{ByteOrder, LittleEndian};

#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("wire: truncated input (needed {needed} more bytes at {at})")]
    Truncated { at: usize, needed: usize },
    #[error("wire: invalid utf-8 string")]
    BadUtf8,
    #[error("wire: length {len} exceeds limit {limit}")]
    TooLong { len: usize, limit: usize },
    #[error("wire: invalid tag {0}")]
    BadTag(u8),
}

/// Hard cap on any single length-prefixed field (guards against corrupt
/// frames allocating unbounded memory). 1 GiB accommodates the "large
/// message" experiments of DESIGN.md E5.
pub const MAX_FIELD: usize = 1 << 30;

#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        let mut b = [0u8; 4];
        LittleEndian::write_u32(&mut b, v);
        self.buf.extend_from_slice(&b);
    }

    pub fn u64(&mut self, v: u64) {
        let mut b = [0u8; 8];
        LittleEndian::write_u64(&mut b, v);
        self.buf.extend_from_slice(&b);
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        assert!(v.len() <= MAX_FIELD);
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// f32 vector as raw little-endian bytes (4-byte aligned copy).
    pub fn f32s(&mut self, v: &[f32]) {
        assert!(v.len() * 4 <= MAX_FIELD);
        self.u32(v.len() as u32);
        let start = self.buf.len();
        self.buf.resize(start + v.len() * 4, 0);
        LittleEndian::write_f32_into(v, &mut self.buf[start..]);
    }

    pub fn i32s(&mut self, v: &[i32]) {
        assert!(v.len() * 4 <= MAX_FIELD);
        self.u32(v.len() as u32);
        let start = self.buf.len();
        self.buf.resize(start + v.len() * 4, 0);
        LittleEndian::write_i32_into(v, &mut self.buf[start..]);
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                at: self.pos,
                needed: n - self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(LittleEndian::read_u32(self.take(4)?))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(LittleEndian::read_u64(self.take(8)?))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len_prefix(&mut self) -> Result<usize, WireError> {
        let len = self.u32()? as usize;
        if len > MAX_FIELD {
            return Err(WireError::TooLong {
                len,
                limit: MAX_FIELD,
            });
        }
        Ok(len)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let len = self.len_prefix()?;
        self.take(len)
    }

    pub fn str(&mut self) -> Result<&'a str, WireError> {
        std::str::from_utf8(self.bytes()?).map_err(|_| WireError::BadUtf8)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        LittleEndian::read_f32_into(raw, &mut out);
        Ok(out)
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        let mut out = vec![0i32; n];
        LittleEndian::read_i32_into(raw, &mut out);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("héllo");
        w.bytes(&[1, 2, 3]);
        w.f32s(&[0.0, -1.0, f32::MAX]);
        w.i32s(&[-5, 0, i32::MAX]);
        let buf = w.into_bytes();

        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.f32s().unwrap(), vec![0.0, -1.0, f32::MAX]);
        assert_eq!(r.i32s().unwrap(), vec![-5, 0, i32::MAX]);
        assert!(r.is_done());
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.str("hello");
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf[..buf.len() - 1]);
        assert!(matches!(r.str(), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn bogus_length_rejected_without_alloc() {
        let mut w = Writer::new();
        w.u32(u32::MAX); // absurd length prefix
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(matches!(r.bytes(), Err(WireError::TooLong { .. })));
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut w = Writer::new();
        w.bytes(&[0xff, 0xfe]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(matches!(r.str(), Err(WireError::BadUtf8)));
    }

    #[test]
    fn f32_bitexact_roundtrip() {
        // The Fig.5 experiment depends on parameters surviving the wire
        // BIT-EXACTLY, including NaN payloads and signed zeros.
        let vals = [0.0f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 1e-40];
        let mut w = Writer::new();
        w.f32s(&vals);
        let buf = w.into_bytes();
        let got = Reader::new(&buf).f32s().unwrap();
        for (a, b) in vals.iter().zip(got.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
