//! Wire-format primitives: a little-endian writer/reader pair used by the
//! transport frames, the FLARE envelope codec, and the Flower message
//! protocol, plus [`Bytes`] — a cheaply-cloneable shared view into an
//! immutable byte buffer that gives the record codec its zero-copy
//! decode path (tensors in a decoded frame are slices of the frame's
//! allocation, not copies). All multi-byte integers are little-endian;
//! byte strings and vectors are u32-length-prefixed.

use std::sync::Arc;

#[derive(Debug)]
pub enum WireError {
    Truncated { at: usize, needed: usize },
    BadUtf8,
    TooLong { len: usize, limit: usize },
    BadTag(u8),
    /// Structurally invalid frame (inconsistent lengths, duplicate
    /// tensor names, ...).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { at, needed } => {
                write!(f, "wire: truncated input (needed {needed} more bytes at {at})")
            }
            WireError::BadUtf8 => write!(f, "wire: invalid utf-8 string"),
            WireError::TooLong { len, limit } => {
                write!(f, "wire: length {len} exceeds limit {limit}")
            }
            WireError::BadTag(t) => write!(f, "wire: invalid tag {t}"),
            WireError::Malformed(what) => write!(f, "wire: malformed frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Hard cap on any single length-prefixed field (guards against corrupt
/// frames allocating unbounded memory). 1 GiB accommodates the "large
/// message" experiments of DESIGN.md E5.
pub const MAX_FIELD: usize = 1 << 30;

// ---------------------------------------------------------------------------
// Shared immutable byte buffer
// ---------------------------------------------------------------------------

/// A view into a reference-counted immutable byte buffer. Cloning and
/// slicing are O(1) and share the underlying allocation — the substrate
/// for zero-copy frame decoding: `Bytes::from_vec(frame)` takes
/// ownership without copying, and every tensor segment decoded out of it
/// is a [`Bytes::slice`] of the same allocation.
#[derive(Clone)]
pub struct Bytes {
    owner: Arc<Vec<u8>>,
    start: usize,
    len: usize,
}

impl Bytes {
    /// Take ownership of `v` without copying.
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let len = v.len();
        Bytes {
            owner: Arc::new(v),
            start: 0,
            len,
        }
    }

    /// Copy `s` into a fresh allocation (records this as a copy in the
    /// telemetry byte-copy counter).
    pub fn copy_from_slice(s: &[u8]) -> Bytes {
        crate::telemetry::bump("bytes.copied", s.len() as i64);
        Bytes::from_vec(s.to_vec())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.owner[self.start..self.start + self.len]
    }

    /// Zero-copy sub-view sharing this buffer's allocation.
    ///
    /// Panics if `start + len` exceeds this view.
    pub fn slice(&self, start: usize, len: usize) -> Bytes {
        assert!(
            start.checked_add(len).is_some_and(|end| end <= self.len),
            "Bytes::slice out of range: {start}+{len} > {}",
            self.len
        );
        Bytes {
            owner: self.owner.clone(),
            start: self.start + start,
            len,
        }
    }

    /// True when `other` is a view into the same allocation as `self`
    /// (used by tests/benches to prove the decode path copied nothing).
    pub fn shares_allocation(&self, other: &Bytes) -> bool {
        Arc::ptr_eq(&self.owner, &other.owner)
    }
}

impl std::ops::Deref for Bytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bytes({} bytes)", self.len)
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::from_vec(v)
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        assert!(v.len() <= MAX_FIELD);
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Raw bytes with NO length prefix (caller wrote the framing).
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// f32 vector as raw little-endian bytes.
    pub fn f32s(&mut self, v: &[f32]) {
        assert!(v.len() * 4 <= MAX_FIELD);
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    pub fn i32s(&mut self, v: &[i32]) {
        assert!(v.len() * 4 <= MAX_FIELD);
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

// ---------------------------------------------------------------------------
// Borrowed reader
// ---------------------------------------------------------------------------

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                at: self.pos,
                needed: n - self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len_prefix(&mut self) -> Result<usize, WireError> {
        let len = self.u32()? as usize;
        if len > MAX_FIELD {
            return Err(WireError::TooLong {
                len,
                limit: MAX_FIELD,
            });
        }
        Ok(len)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let len = self.len_prefix()?;
        self.take(len)
    }

    pub fn str(&mut self) -> Result<&'a str, WireError> {
        std::str::from_utf8(self.bytes()?).map_err(|_| WireError::BadUtf8)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.len_prefix()?;
        if n.checked_mul(4).is_none() {
            return Err(WireError::TooLong {
                len: n,
                limit: MAX_FIELD,
            });
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.len_prefix()?;
        if n.checked_mul(4).is_none() {
            return Err(WireError::TooLong {
                len: n,
                limit: MAX_FIELD,
            });
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Shared (zero-copy) reader
// ---------------------------------------------------------------------------

/// Reader over a shared [`Bytes`] buffer. Scalar reads behave like
/// [`Reader`]; [`FrameReader::take_shared`] / [`FrameReader::bytes_shared`]
/// return sub-views that alias the underlying allocation instead of
/// copying — the decode path of the record codec.
pub struct FrameReader {
    buf: Bytes,
    pos: usize,
}

impl FrameReader {
    pub fn new(buf: Bytes) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn view(&self, n: usize) -> Result<&[u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                at: self.pos,
                needed: n - self.remaining(),
            });
        }
        Ok(&self.buf.as_slice()[self.pos..self.pos + n])
    }

    /// Zero-copy: the returned [`Bytes`] shares the frame's allocation.
    pub fn take_shared(&mut self, n: usize) -> Result<Bytes, WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                at: self.pos,
                needed: n - self.remaining(),
            });
        }
        let out = self.buf.slice(self.pos, n);
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        let v = self.view(1)?[0];
        self.pos += 1;
        Ok(v)
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.view(4)?;
        let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        self.pos += 4;
        Ok(v)
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.view(8)?;
        let v = u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]);
        self.pos += 8;
        Ok(v)
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len_prefix(&mut self) -> Result<usize, WireError> {
        let len = self.u32()? as usize;
        if len > MAX_FIELD {
            return Err(WireError::TooLong {
                len,
                limit: MAX_FIELD,
            });
        }
        Ok(len)
    }

    /// Length-prefixed bytes as a zero-copy sub-view.
    pub fn bytes_shared(&mut self) -> Result<Bytes, WireError> {
        let len = self.len_prefix()?;
        self.take_shared(len)
    }

    /// Length-prefixed UTF-8 string (strings are small; this copies).
    pub fn str(&mut self) -> Result<String, WireError> {
        let len = self.len_prefix()?;
        let view = self.view(len)?;
        let s = std::str::from_utf8(view)
            .map_err(|_| WireError::BadUtf8)?
            .to_string();
        self.pos += len;
        Ok(s)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.len_prefix()?;
        if n.checked_mul(4).is_none() {
            return Err(WireError::TooLong {
                len: n,
                limit: MAX_FIELD,
            });
        }
        let raw = self.view(n * 4)?;
        let out = raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect();
        self.pos += n * 4;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("héllo");
        w.bytes(&[1, 2, 3]);
        w.f32s(&[0.0, -1.0, f32::MAX]);
        w.i32s(&[-5, 0, i32::MAX]);
        let buf = w.into_bytes();

        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.f32s().unwrap(), vec![0.0, -1.0, f32::MAX]);
        assert_eq!(r.i32s().unwrap(), vec![-5, 0, i32::MAX]);
        assert!(r.is_done());
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.str("hello");
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf[..buf.len() - 1]);
        assert!(matches!(r.str(), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn bogus_length_rejected_without_alloc() {
        let mut w = Writer::new();
        w.u32(u32::MAX); // absurd length prefix
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(matches!(r.bytes(), Err(WireError::TooLong { .. })));
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut w = Writer::new();
        w.bytes(&[0xff, 0xfe]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(matches!(r.str(), Err(WireError::BadUtf8)));
    }

    #[test]
    fn f32_bitexact_roundtrip() {
        // The Fig.5 experiment depends on parameters surviving the wire
        // BIT-EXACTLY, including NaN payloads and signed zeros.
        let vals = [0.0f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 1e-40];
        let mut w = Writer::new();
        w.f32s(&vals);
        let buf = w.into_bytes();
        let got = Reader::new(&buf).f32s().unwrap();
        for (a, b) in vals.iter().zip(got.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bytes_slicing_shares_allocation() {
        let b = Bytes::from_vec((0u8..64).collect());
        let s = b.slice(10, 20);
        assert_eq!(s.len(), 20);
        assert_eq!(s.as_slice()[0], 10);
        assert!(b.shares_allocation(&s));
        let s2 = s.slice(5, 5);
        assert_eq!(s2.as_slice(), &[15, 16, 17, 18, 19]);
        assert!(b.shares_allocation(&s2));
        let other = Bytes::from_vec(vec![1, 2, 3]);
        assert!(!b.shares_allocation(&other));
    }

    #[test]
    #[should_panic]
    fn bytes_slice_out_of_range_panics() {
        Bytes::from_vec(vec![0; 4]).slice(2, 3);
    }

    #[test]
    fn frame_reader_zero_copy_and_scalars() {
        let mut w = Writer::new();
        w.u8(9);
        w.u64(77);
        w.str("name");
        w.bytes(&[4, 5, 6, 7]);
        let frame = Bytes::from_vec(w.into_bytes());
        let mut fr = FrameReader::new(frame.clone());
        assert_eq!(fr.u8().unwrap(), 9);
        assert_eq!(fr.u64().unwrap(), 77);
        assert_eq!(fr.str().unwrap(), "name");
        let payload = fr.bytes_shared().unwrap();
        assert_eq!(payload.as_slice(), &[4, 5, 6, 7]);
        assert!(frame.shares_allocation(&payload), "decode must not copy");
        assert!(fr.is_done());
    }

    #[test]
    fn frame_reader_truncation_detected() {
        let mut w = Writer::new();
        w.bytes(&[1, 2, 3, 4, 5]);
        let mut buf = w.into_bytes();
        buf.truncate(buf.len() - 2);
        let mut fr = FrameReader::new(Bytes::from_vec(buf));
        assert!(matches!(
            fr.bytes_shared(),
            Err(WireError::Truncated { .. })
        ));
    }
}
