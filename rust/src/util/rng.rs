//! Deterministic PRNG substrate (no `rand` crate offline): SplitMix64 for
//! seeding/stream-splitting and Xoshiro256++ for bulk generation, plus the
//! Box–Muller transform for normals.
//!
//! Every stochastic component in the system (synthetic data, client
//! sampling, fault injection, property tests) draws from these so that
//! *every run is bit-reproducible from a single u64 seed* — the property
//! the paper's Fig. 5 experiment depends on.

/// SplitMix64 — tiny, full-period 2^64 generator; the canonical seeder.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality 256-bit state generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (e.g. per client, per round).
    /// Mixes the label into the seed path so `split(a) != split(b)`.
    pub fn split(&mut self, label: u64) -> Rng {
        let mixed = self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (deterministic, caches the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n), in shuffled order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k {} > n {}", k, n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 (from the public-domain impl).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn rng_deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(7);
        let mut x = root.split(1);
        let mut root2 = Rng::new(7);
        let mut y = root2.split(2);
        let xs: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| y.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
    }

    #[test]
    #[should_panic]
    fn sample_more_than_n_panics() {
        Rng::new(0).sample_indices(3, 4);
    }
}
