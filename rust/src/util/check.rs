//! Mini property-testing framework (proptest is unavailable offline).
//!
//! PRNG-driven case generation with bounded shrinking: when a case fails,
//! the framework re-runs the property on progressively "smaller" inputs
//! derived by the generator's `shrink` method and reports the smallest
//! failing case found. Used by the coordinator-invariant tests (routing,
//! batching, scheduler state) per the session guide.
//!
//! ```ignore
//! prop_check("sort is idempotent", 200, gen_vec(gen_u64(0, 100), 0, 50), |v| {
//!     let mut a = v.clone();
//!     a.sort();
//!     let mut b = a.clone();
//!     b.sort();
//!     a == b
//! });
//! ```

use crate::util::rng::Rng;

/// A generator of values of type T plus a shrinking rule.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of `v`, most aggressive first.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `cases` random cases of `prop`; panic with the smallest failing
/// input if any fail. Deterministic given the seed baked from the name.
pub fn prop_check<G: Gen>(name: &str, cases: usize, gen: G, prop: impl Fn(&G::Value) -> bool) {
    let seed = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let min = shrink_to_min(&gen, v, &prop);
            panic!(
                "property '{}' failed at case {}/{}.\nminimal counterexample: {:?}",
                name, i + 1, cases, min
            );
        }
    }
}

fn shrink_to_min<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Generator combinators
// ---------------------------------------------------------------------------

pub struct U64Gen {
    pub lo: u64,
    pub hi: u64,
}

pub fn gen_u64(lo: u64, hi: u64) -> U64Gen {
    U64Gen { lo, hi }
}

impl Gen for U64Gen {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range_u64(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

pub struct F32Gen {
    pub lo: f32,
    pub hi: f32,
}

pub fn gen_f32(lo: f32, hi: f32) -> F32Gen {
    F32Gen { lo, hi }
}

impl Gen for F32Gen {
    type Value = f32;
    fn generate(&self, rng: &mut Rng) -> f32 {
        self.lo + (self.hi - self.lo) * rng.next_f32()
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        if (*v - self.lo).abs() > 1e-6 {
            vec![self.lo, self.lo + (*v - self.lo) / 2.0]
        } else {
            Vec::new()
        }
    }
}

pub struct VecGen<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn gen_vec<G: Gen>(inner: G, min_len: usize, max_len: usize) -> VecGen<G> {
    VecGen {
        inner,
        min_len,
        max_len,
    }
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Drop halves, then single elements.
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            if v.len() > 1 {
                out.push(v[1..].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
        }
        // Shrink one element.
        for (i, x) in v.iter().enumerate().take(8) {
            for sx in self.inner.shrink(x) {
                let mut c = v.clone();
                c[i] = sx;
                out.push(c);
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairGen<A, B>(pub A, pub B);

pub fn gen_pair<A: Gen, B: Gen>(a: A, b: B) -> PairGen<A, B> {
    PairGen(a, b)
}

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("rev rev is id", 100, gen_vec(gen_u64(0, 100), 0, 20), |v| {
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            r == *v
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            prop_check(
                "all vecs shorter than 3",
                200,
                gen_vec(gen_u64(0, 10), 0, 20),
                |v| v.len() < 3,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Shrinker should find a minimal 3-element counterexample.
        assert!(msg.contains("minimal counterexample"), "{}", msg);
        let after = msg.split("counterexample: ").nth(1).unwrap();
        let commas = after.matches(',').count();
        assert!(commas <= 2, "not minimal: {}", after);
    }

    #[test]
    fn deterministic_given_name() {
        // Same property name => same cases => same first failure.
        let run = || {
            std::panic::catch_unwind(|| {
                prop_check("det", 50, gen_u64(0, 1000), |v| *v < 500);
            })
        };
        let a = format!("{:?}", run().unwrap_err().downcast::<String>().unwrap());
        let b = format!("{:?}", run().unwrap_err().downcast::<String>().unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = gen_pair(gen_u64(0, 100), gen_u64(0, 100));
        let shrunk = g.shrink(&(50, 50));
        assert!(shrunk.iter().any(|(a, _)| *a < 50));
        assert!(shrunk.iter().any(|(_, b)| *b < 50));
    }
}
