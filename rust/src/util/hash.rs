//! Hand-rolled SHA-256 / HMAC-SHA256 / hex, vendored-dep-free like the
//! CRC table in `persist/wal.rs`. The offline container has no `sha2` /
//! `hmac` crates, so the provisioning tokens and the per-node frame MACs
//! (see [`crate::flower::authn`]) are built on this module. FIPS 180-4
//! SHA-256 and RFC 2104 HMAC, verified against the standard test vectors
//! below; **not** constant-time and not a substitute for real TLS — see
//! DESIGN.md §Substitutions.

/// SHA-256 round constants (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Self {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // Padding: 0x80, zeros, 64-bit big-endian length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Manual final block write: update() would also bump total_len,
        // so splice the length bytes straight into the buffer.
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(big_s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// Incremental HMAC-SHA256 (RFC 2104).
#[derive(Clone)]
pub struct HmacSha256 {
    inner: Sha256,
    opad_key: [u8; 64],
}

impl HmacSha256 {
    pub fn new(key: &[u8]) -> Self {
        let mut block = [0u8; 64];
        if key.len() > 64 {
            block[..32].copy_from_slice(&sha256(key));
        } else {
            block[..key.len()].copy_from_slice(key);
        }
        let mut ipad_key = [0u8; 64];
        let mut opad_key = [0u8; 64];
        for i in 0..64 {
            ipad_key[i] = block[i] ^ 0x36;
            opad_key[i] = block[i] ^ 0x5c;
        }
        let mut inner = Sha256::new();
        inner.update(&ipad_key);
        Self { inner, opad_key }
    }

    pub fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    pub fn finalize(self) -> [u8; 32] {
        let inner_digest = self.inner.finalize();
        let mut outer = Sha256::new();
        outer.update(&self.opad_key);
        outer.update(&inner_digest);
        outer.finalize()
    }
}

/// One-shot HMAC-SHA256.
pub fn hmac_sha256(key: &[u8], data: &[u8]) -> [u8; 32] {
    let mut mac = HmacSha256::new(key);
    mac.update(data);
    mac.finalize()
}

/// Constant-shape comparison of two MACs (no early exit on mismatch; the
/// best we model without a real constant-time crate).
pub fn macs_equal(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        diff |= x ^ y;
    }
    diff == 0
}

/// Lowercase hex of `bytes`.
pub fn hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(DIGITS[(b >> 4) as usize] as char);
        s.push(DIGITS[(b & 0xf) as usize] as char);
    }
    s
}

/// Inverse of [`hex`]; `None` on odd length or non-hex characters.
pub fn unhex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(s.get(i..i + 2)?, 16).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST known-answer vectors.
    #[test]
    fn sha256_empty() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn sha256_abc() {
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn sha256_two_blocks() {
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_million_a() {
        let data = vec![b'a'; 1_000_000];
        assert_eq!(
            hex(&sha256(&data)),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn sha256_incremental_matches_oneshot() {
        let data: Vec<u8> = (0..255u8).collect();
        for split in [0usize, 1, 63, 64, 65, 128, 200, 255] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha256(&data), "split {split}");
        }
    }

    // RFC 4231 HMAC-SHA256 test cases.
    #[test]
    fn hmac_rfc4231_case1() {
        let key = [0x0bu8; 20];
        assert_eq!(
            hex(&hmac_sha256(&key, b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn hmac_rfc4231_case2() {
        assert_eq!(
            hex(&hmac_sha256(
                b"Jefe",
                b"what do ya want for nothing?"
            )),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn hmac_rfc4231_case6_long_key() {
        // 131-byte key forces the hash-the-key path.
        let key = [0xaau8; 131];
        assert_eq!(
            hex(&hmac_sha256(
                &key,
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hmac_incremental_matches_oneshot() {
        let mut mac = HmacSha256::new(b"key");
        mac.update(b"part one|");
        mac.update(b"part two");
        assert_eq!(mac.finalize(), hmac_sha256(b"key", b"part one|part two"));
    }

    #[test]
    fn macs_equal_checks() {
        let a = sha256(b"x");
        let mut b = a;
        assert!(macs_equal(&a, &b));
        b[31] ^= 1;
        assert!(!macs_equal(&a, &b));
        assert!(!macs_equal(&a[..16], &a));
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(unhex(&hex(&[0, 255, 16])).unwrap(), vec![0, 255, 16]);
        assert!(unhex("abc").is_none());
        assert!(unhex("zz").is_none());
    }
}
