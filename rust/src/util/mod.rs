//! Foundation utilities built from scratch for the offline environment:
//! deterministic PRNG, JSON, wire codec, bench harness, and a mini
//! property-testing framework.

pub mod bench;
pub mod bytes;
pub mod check;
pub mod hash;
pub mod json;
pub mod rng;

/// Current wall-clock in milliseconds since the UNIX epoch (telemetry only;
/// never used for control flow).
pub fn unix_millis() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}
