//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with p50/p95/mean statistics and aligned table output. Every
//! `rust/benches/*.rs` target (harness = false) uses this to print the
//! rows for the paper's figures/claims.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((iters as f64 - 1.0) * p) as usize];
        Stats {
            iters,
            mean: total / iters as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            min: samples[0],
            max: samples[iters - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    Stats::from_samples(samples)
}

/// Time `f` until at least `min_time` has elapsed (min 5 iterations).
pub fn bench_for<T>(warmup: usize, min_time: Duration, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 5 || start.elapsed() < min_time {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
        if samples.len() > 1_000_000 {
            break;
        }
    }
    Stats::from_samples(samples)
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{}ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Simple aligned table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn stat_row(&mut self, label: &str, extra: &[String], s: &Stats) {
        let mut cells = vec![label.to_string()];
        cells.extend_from_slice(extra);
        cells.extend([
            fmt_dur(s.p50),
            fmt_dur(s.p95),
            fmt_dur(s.mean),
            s.iters.to_string(),
        ]);
        self.row(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = Stats::from_samples(samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.iters, 100);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = bench(2, 10, || {
            count += 1;
            count
        });
        assert_eq!(s.iters, 10);
        assert_eq!(count, 12);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
    }
}
